//! [`FleetMetrics`] — what one fleet simulation is judged by.

use std::collections::BTreeMap;

use crate::util::stats::{percentile, QuantileSketch, SKETCH_EXACT_LIMIT};

/// Jain's fairness index over non-negative per-user allocations:
/// `(Σx)² / (n·Σx²)`, in `(0, 1]` for any non-degenerate input; `1.0`
/// exactly when every user received the same amount — and by
/// convention for the vacuous cases (no users, or no service handed
/// out at all).
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sum <= 0.0 || sq <= 0.0 {
        return 1.0;
    }
    (sum * sum) / (xs.len() as f64 * sq)
}

/// Per-job outcome, indexed by job id in [`FleetMetrics::per_job`].
#[derive(Debug, Clone, PartialEq)]
pub struct JobStat {
    pub id: usize,
    pub user: usize,
    pub arrival: f64,
    /// First instant any attempt of this job started (`None` = never
    /// placed).
    pub first_start: Option<f64>,
    /// Completion instant (`None` = failed or incomplete).
    pub finish: Option<f64>,
    /// Absolute deadline (`f64::INFINITY` when deadlines are disabled
    /// or the job has no feasible full-pool reference plan).
    pub deadline: f64,
    /// Completed at or before its deadline.
    pub met: bool,
}

/// Per-user SLO aggregate in [`FleetMetrics::per_user`].
#[derive(Debug, Clone, PartialEq)]
pub struct UserStat {
    pub user: usize,
    /// Jobs this user submitted.
    pub jobs: usize,
    pub completed: usize,
    /// Jobs completed within their deadline.
    pub met: usize,
    /// p95 completion latency over the user's completed jobs, seconds.
    pub p95: Option<f64>,
    /// Device-seconds this user's jobs occupied.
    pub service: f64,
}

/// Raw tallies the simulator hands to [`FleetMetrics::assemble`].
pub(crate) struct RawFleet {
    /// One entry per job, ascending id.
    pub per_job: Vec<JobStat>,
    /// Jobs proven unplaceable.
    pub failed: usize,
    /// Virtual time at which the simulation ended, seconds.
    pub makespan: f64,
    /// (id, busy seconds, presence seconds) per device.
    pub per_device: Vec<(usize, f64, f64)>,
    /// (user, device-seconds consumed) pairs, ascending user.
    pub user_service: Vec<(usize, f64)>,
    pub replans: usize,
    pub restarts: usize,
    pub work_lost: f64,
    pub migration_overhead: f64,
    pub ckpt_count: usize,
    pub ckpt_overhead: f64,
    pub events: usize,
    /// Oracle memo-cache hits / misses (observe counters).
    pub oracle_hits: usize,
    pub oracle_misses: usize,
    /// Dispatch attempts answered from incremental queue-policy state
    /// without a full-queue rescan (observe counter).
    pub rescans_avoided: usize,
}

/// Aggregate outcome of one fleet run. All fields are deterministic
/// functions of (pool, traces, policies, strategy, options): the
/// determinism property test compares whole values with `==`.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetMetrics {
    /// Jobs that finished within the horizon.
    pub completed: usize,
    /// Jobs proven unplaceable (infeasible even on the full pool with
    /// no joins pending).
    pub failed: usize,
    /// Jobs still queued or running when the horizon closed.
    pub incomplete: usize,
    /// Virtual time at which the simulation ended, seconds.
    pub makespan: f64,
    /// Completed jobs per hour of makespan.
    pub jobs_per_hour: f64,
    /// Jobs completed within their deadline.
    pub deadline_met: usize,
    /// Deadline-met jobs per hour of makespan (the fleet's goodput).
    pub goodput_per_hour: f64,
    /// Fraction of all submitted jobs that did *not* complete within
    /// their deadline (unfinished jobs count as misses — conservative).
    pub deadline_miss_rate: f64,
    /// Completion-latency (finish − arrival) percentiles over the
    /// completed jobs, seconds. Empty runs report `None`.
    pub latency_p50: Option<f64>,
    pub latency_p95: Option<f64>,
    pub latency_p99: Option<f64>,
    /// Mean busy fraction across devices, weighted by each device's
    /// presence time in the pool.
    pub utilization: f64,
    /// Per-device (id, busy/presence) pairs, ascending id.
    pub per_device_util: Vec<(usize, f64)>,
    /// Jain fairness index over per-user device-seconds, in (0, 1];
    /// 1.0 for a single-user trace.
    pub fairness: f64,
    /// Per-job outcomes, ascending job id.
    pub per_job: Vec<JobStat>,
    /// Per-user SLO aggregates, ascending user id.
    pub per_user: Vec<UserStat>,
    /// Replans triggered by churn (preempt-and-replan policies).
    pub replans: usize,
    /// Attempts aborted by churn (restart policies, or replans whose
    /// survivors could not host the job).
    pub restarts: usize,
    /// Seconds of job execution discarded by churn-forced restarts.
    /// Without checkpointing this is the whole placement chain; with it,
    /// only the work since the last completed checkpoint (expressed at
    /// the aborted attempt's service rate).
    pub work_lost: f64,
    /// Checkpoint/activation-cache migration seconds paid by replans.
    pub migration_overhead: f64,
    /// Checkpoints completed across all attempts.
    pub ckpt_count: usize,
    /// Seconds spent checkpointing, partial (churn-cut) pauses included.
    pub ckpt_overhead: f64,
    /// Events processed by the event loop (throughput denominator for
    /// `bench_fleet`).
    pub events: usize,
    /// Strategy-oracle memo-cache hits across the run (observe
    /// counter: the planner calls the cache absorbed).
    pub oracle_hits: usize,
    /// Strategy-oracle memo-cache misses (planner calls actually paid).
    pub oracle_misses: usize,
    /// Dispatch attempts answered from incremental queue-policy state
    /// without rescanning/re-sorting the whole queue (observe counter
    /// for the O(log n) dispatch path).
    pub rescans_avoided: usize,
}

impl FleetMetrics {
    /// Assemble the derived fields from the raw tallies the simulator
    /// accumulated.
    pub(crate) fn assemble(raw: RawFleet) -> FleetMetrics {
        let n_jobs = raw.per_job.len();
        // Latencies stream through the quantile sketch in job-id order:
        // exact (bit-identical to collect-and-sort) below
        // SKETCH_EXACT_LIMIT completions, fixed-state P² beyond it.
        let mut sketch = QuantileSketch::new(&[0.50, 0.95, 0.99], SKETCH_EXACT_LIMIT);
        for j in &raw.per_job {
            if let Some(f) = j.finish {
                sketch.add(f - j.arrival);
            }
        }
        let completed = sketch.len();
        let incomplete = n_jobs - completed - raw.failed;
        let lat = sketch.quantile_many(&[0.50, 0.95, 0.99]);
        let deadline_met = raw.per_job.iter().filter(|j| j.met).count();
        let hours = raw.makespan / 3600.0;
        let per_hour = |n: usize| if hours > 0.0 { n as f64 / hours } else { 0.0 };

        // per-user aggregation (BTreeMap: deterministic ascending order)
        #[derive(Default)]
        struct UserAcc {
            jobs: usize,
            completed: usize,
            met: usize,
            lats: Vec<f64>,
        }
        let mut users: BTreeMap<usize, UserAcc> = BTreeMap::new();
        for j in &raw.per_job {
            let acc = users.entry(j.user).or_default();
            acc.jobs += 1;
            if let Some(f) = j.finish {
                acc.completed += 1;
                acc.lats.push(f - j.arrival);
            }
            if j.met {
                acc.met += 1;
            }
        }
        let service: BTreeMap<usize, f64> = raw.user_service.iter().copied().collect();
        let per_user: Vec<UserStat> = users
            .into_iter()
            .map(|(user, mut acc)| {
                acc.lats.sort_by(|a, b| a.total_cmp(b));
                UserStat {
                    user,
                    jobs: acc.jobs,
                    completed: acc.completed,
                    met: acc.met,
                    p95: percentile(&acc.lats, 0.95),
                    service: service.get(&user).copied().unwrap_or(0.0),
                }
            })
            .collect();
        let shares: Vec<f64> = per_user.iter().map(|u| u.service).collect();
        let fairness = jain_index(&shares);

        let (busy, presence) = raw
            .per_device
            .iter()
            .fold((0.0, 0.0), |(b, p), (_, db, dp)| (b + db, p + dp));
        let per_device_util: Vec<(usize, f64)> = raw
            .per_device
            .into_iter()
            .map(|(id, b, p)| (id, if p > 0.0 { b / p } else { 0.0 }))
            .collect();

        FleetMetrics {
            completed,
            failed: raw.failed,
            incomplete,
            makespan: raw.makespan,
            jobs_per_hour: per_hour(completed),
            deadline_met,
            goodput_per_hour: per_hour(deadline_met),
            deadline_miss_rate: if n_jobs > 0 {
                1.0 - deadline_met as f64 / n_jobs as f64
            } else {
                0.0
            },
            latency_p50: lat[0],
            latency_p95: lat[1],
            latency_p99: lat[2],
            utilization: if presence > 0.0 { busy / presence } else { 0.0 },
            per_device_util,
            fairness,
            per_job: raw.per_job,
            per_user,
            replans: raw.replans,
            restarts: raw.restarts,
            work_lost: raw.work_lost,
            migration_overhead: raw.migration_overhead,
            ckpt_count: raw.ckpt_count,
            ckpt_overhead: raw.ckpt_overhead,
            events: raw.events,
            oracle_hits: raw.oracle_hits,
            oracle_misses: raw.oracle_misses,
            rescans_avoided: raw.rescans_avoided,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(
        id: usize,
        user: usize,
        arrival: f64,
        finish: Option<f64>,
        deadline: f64,
    ) -> JobStat {
        JobStat {
            id,
            user,
            arrival,
            first_start: finish.map(|_| arrival),
            finish,
            deadline,
            met: finish.map(|f| f <= deadline).unwrap_or(false),
        }
    }

    fn raw(per_job: Vec<JobStat>, failed: usize, makespan: f64) -> RawFleet {
        RawFleet {
            per_job,
            failed,
            makespan,
            per_device: vec![],
            user_service: vec![],
            replans: 0,
            restarts: 0,
            work_lost: 0.0,
            migration_overhead: 0.0,
            ckpt_count: 0,
            ckpt_overhead: 0.0,
            events: 0,
            oracle_hits: 0,
            oracle_misses: 0,
            rescans_avoided: 0,
        }
    }

    #[test]
    fn assemble_computes_percentiles_rates_and_deadlines() {
        let per_job = vec![
            stat(0, 0, 0.0, Some(10.0), 100.0),
            stat(1, 0, 0.0, Some(20.0), 100.0),
            stat(2, 1, 0.0, Some(30.0), 25.0), // completed but missed
            stat(3, 1, 0.0, Some(40.0), 100.0),
            stat(4, 2, 0.0, None, 100.0), // failed
            stat(5, 2, 0.0, None, 100.0), // incomplete
            stat(6, 2, 0.0, None, 100.0), // incomplete
        ];
        let mut r = raw(per_job, 1, 7200.0);
        r.per_device = vec![(0, 3600.0, 7200.0), (1, 1800.0, 3600.0)];
        r.user_service = vec![(0, 100.0), (1, 100.0), (2, 100.0)];
        r.replans = 3;
        r.restarts = 4;
        r.events = 99;
        r.oracle_hits = 5;
        r.oracle_misses = 2;
        r.rescans_avoided = 11;
        let m = FleetMetrics::assemble(r);
        assert_eq!((m.completed, m.failed, m.incomplete), (4, 1, 2));
        assert!((m.jobs_per_hour - 2.0).abs() < 1e-12);
        assert_eq!(m.deadline_met, 3);
        assert!((m.goodput_per_hour - 1.5).abs() < 1e-12);
        assert!((m.deadline_miss_rate - 4.0 / 7.0).abs() < 1e-12);
        assert!((m.latency_p50.unwrap() - 25.0).abs() < 1e-9);
        assert!(m.latency_p99.unwrap() <= 40.0);
        // utilization is presence-weighted: (3600+1800)/(7200+3600)
        assert!((m.utilization - 0.5).abs() < 1e-12);
        assert_eq!(m.per_device_util, vec![(0, 0.5), (1, 0.5)]);
        assert_eq!((m.replans, m.restarts, m.events), (3, 4, 99));
        assert_eq!((m.oracle_hits, m.oracle_misses, m.rescans_avoided), (5, 2, 11));
        // equal per-user service: perfectly fair
        assert!((m.fairness - 1.0).abs() < 1e-12);
        assert_eq!(m.per_user.len(), 3);
        assert_eq!((m.per_user[0].jobs, m.per_user[0].completed, m.per_user[0].met), (2, 2, 2));
        assert_eq!((m.per_user[1].jobs, m.per_user[1].met), (2, 1));
        assert_eq!(m.per_user[2].completed, 0);
        assert_eq!(m.per_user[2].p95, None);
    }

    /// Zero completed jobs: every rate is a clean zero, every
    /// percentile `None` — no NaN or divide-by-zero anywhere.
    #[test]
    fn empty_run_has_no_nans() {
        let m = FleetMetrics::assemble(raw(vec![], 0, 0.0));
        assert_eq!((m.completed, m.failed, m.incomplete), (0, 0, 0));
        assert_eq!(m.latency_p50, None);
        assert_eq!(m.latency_p95, None);
        assert_eq!(m.jobs_per_hour, 0.0);
        assert_eq!(m.goodput_per_hour, 0.0);
        assert_eq!(m.deadline_miss_rate, 0.0);
        assert_eq!(m.utilization, 0.0);
        assert_eq!(m.fairness, 1.0, "vacuous fairness is perfect");
        assert!(m.per_user.is_empty());
        // all-incomplete run: still no NaN
        let m = FleetMetrics::assemble(raw(vec![stat(0, 0, 5.0, None, 10.0)], 0, 3600.0));
        assert_eq!(m.completed, 0);
        assert_eq!(m.incomplete, 1);
        assert_eq!(m.deadline_miss_rate, 1.0);
        assert!(m.goodput_per_hour == 0.0 && !m.goodput_per_hour.is_nan());
        assert_eq!(m.per_user[0].p95, None);
        assert_eq!(m.fairness, 1.0, "no service handed out at all");
    }

    /// A single-event (one-job) trace: percentiles collapse to the one
    /// latency, fairness is exactly 1.0.
    #[test]
    fn single_job_trace() {
        let mut r = raw(vec![stat(0, 7, 10.0, Some(110.0), 500.0)], 0, 200.0);
        r.user_service = vec![(7, 100.0)];
        let m = FleetMetrics::assemble(r);
        assert_eq!(m.completed, 1);
        assert_eq!(m.latency_p50, Some(100.0));
        assert_eq!(m.latency_p95, Some(100.0));
        assert_eq!(m.latency_p99, Some(100.0));
        assert_eq!(m.fairness, 1.0);
        assert_eq!(m.per_user, vec![UserStat {
            user: 7,
            jobs: 1,
            completed: 1,
            met: 1,
            p95: Some(100.0),
            service: 100.0,
        }]);
        assert_eq!(m.deadline_met, 1);
        assert_eq!(m.deadline_miss_rate, 0.0);
    }

    /// Exact percentile indexing at small n: two latencies interpolate
    /// linearly, matching `util::stats::percentile` to the bit.
    #[test]
    fn small_n_percentiles_are_exact() {
        let per_job = vec![
            stat(0, 0, 0.0, Some(10.0), f64::INFINITY),
            stat(1, 0, 0.0, Some(20.0), f64::INFINITY),
        ];
        let m = FleetMetrics::assemble(raw(per_job, 0, 100.0));
        assert_eq!(m.latency_p50, Some(15.0));
        assert!((m.latency_p95.unwrap() - 19.5).abs() < 1e-12);
        assert!((m.latency_p99.unwrap() - 19.9).abs() < 1e-12);
        // infinite deadlines: everything completed counts as met
        assert_eq!(m.deadline_met, 2);
    }

    #[test]
    fn jain_index_properties() {
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[5.0]), 1.0);
        assert!((jain_index(&[3.0, 3.0, 3.0]) - 1.0).abs() < 1e-12);
        // one user hogging everything among n: J = 1/n
        assert!((jain_index(&[9.0, 0.0, 0.0]) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0, "no service at all is vacuously fair");
        let j = jain_index(&[1.0, 2.0, 3.0, 4.0]);
        assert!(j > 0.0 && j <= 1.0);
    }
}
