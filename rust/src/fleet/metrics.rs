//! [`FleetMetrics`] — what one fleet simulation is judged by.

use crate::util::stats::percentile;

/// Aggregate outcome of one fleet run. All fields are deterministic
/// functions of (pool, traces, policy, strategy, horizon): the
/// determinism property test compares whole values with `==`.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetMetrics {
    /// Jobs that finished within the horizon.
    pub completed: usize,
    /// Jobs proven unplaceable (infeasible even on the full pool with
    /// no joins pending).
    pub failed: usize,
    /// Jobs still queued or running when the horizon closed.
    pub incomplete: usize,
    /// Virtual time at which the simulation ended, seconds.
    pub makespan: f64,
    /// Completed jobs per hour of makespan.
    pub jobs_per_hour: f64,
    /// Completion-latency (finish − arrival) percentiles over the
    /// completed jobs, seconds. Empty runs report `None`.
    pub latency_p50: Option<f64>,
    pub latency_p95: Option<f64>,
    pub latency_p99: Option<f64>,
    /// Mean busy fraction across devices, weighted by each device's
    /// presence time in the pool.
    pub utilization: f64,
    /// Per-device (id, busy/presence) pairs, ascending id.
    pub per_device_util: Vec<(usize, f64)>,
    /// Replans triggered by churn (preempt-and-replan policies).
    pub replans: usize,
    /// Attempts aborted by churn (restart policies, or replans whose
    /// survivors could not host the job).
    pub restarts: usize,
    /// Wall-clock seconds of job execution discarded by churn-forced
    /// restarts (the whole placement chain, progress preserved by
    /// intermediate replans included).
    pub work_lost: f64,
    /// Checkpoint/activation-cache migration seconds paid by replans.
    pub migration_overhead: f64,
    /// Events processed by the event loop (throughput denominator for
    /// `bench_fleet`).
    pub events: usize,
}

impl FleetMetrics {
    /// Assemble the derived fields from the raw tallies the simulator
    /// accumulated. `latencies` need not be sorted.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn assemble(
        mut latencies: Vec<f64>,
        failed: usize,
        incomplete: usize,
        makespan: f64,
        per_device_util: Vec<(usize, f64, f64)>, // (id, busy, presence)
        replans: usize,
        restarts: usize,
        work_lost: f64,
        migration_overhead: f64,
        events: usize,
    ) -> FleetMetrics {
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let completed = latencies.len();
        let pct = |q: f64| (!latencies.is_empty()).then(|| percentile(&latencies, q));
        let (busy, presence) = per_device_util
            .iter()
            .fold((0.0, 0.0), |(b, p), (_, db, dp)| (b + db, p + dp));
        let per_device_util: Vec<(usize, f64)> = per_device_util
            .into_iter()
            .map(|(id, b, p)| (id, if p > 0.0 { b / p } else { 0.0 }))
            .collect();
        FleetMetrics {
            completed,
            failed,
            incomplete,
            makespan,
            jobs_per_hour: if makespan > 0.0 {
                completed as f64 / (makespan / 3600.0)
            } else {
                0.0
            },
            latency_p50: pct(0.50),
            latency_p95: pct(0.95),
            latency_p99: pct(0.99),
            utilization: if presence > 0.0 { busy / presence } else { 0.0 },
            per_device_util,
            replans,
            restarts,
            work_lost,
            migration_overhead,
            events,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assemble_computes_percentiles_and_rates() {
        let m = FleetMetrics::assemble(
            vec![30.0, 10.0, 20.0, 40.0],
            1,
            2,
            7200.0,
            vec![(0, 3600.0, 7200.0), (1, 1800.0, 3600.0)],
            3,
            4,
            55.0,
            5.5,
            99,
        );
        assert_eq!(m.completed, 4);
        assert_eq!(m.failed, 1);
        assert_eq!(m.incomplete, 2);
        assert!((m.jobs_per_hour - 2.0).abs() < 1e-12);
        assert!((m.latency_p50.unwrap() - 25.0).abs() < 1e-9);
        assert!(m.latency_p99.unwrap() <= 40.0);
        // utilization is presence-weighted: (3600+1800)/(7200+3600)
        assert!((m.utilization - 0.5).abs() < 1e-12);
        assert_eq!(m.per_device_util, vec![(0, 0.5), (1, 0.5)]);
        assert_eq!((m.replans, m.restarts, m.events), (3, 4, 99));
    }

    #[test]
    fn empty_run_has_no_percentiles() {
        let m = FleetMetrics::assemble(vec![], 0, 0, 0.0, vec![], 0, 0, 0.0, 0.0, 0);
        assert_eq!(m.completed, 0);
        assert_eq!(m.latency_p50, None);
        assert_eq!(m.jobs_per_hour, 0.0);
        assert_eq!(m.utilization, 0.0);
    }
}
