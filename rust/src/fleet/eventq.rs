//! Pluggable event queues for the discrete-event simulators.
//!
//! The fleet event loop pops entries in `(time, seq)` order — `f64`
//! times under `total_cmp`, the monotone insertion sequence breaking
//! ties. That order is what makes runs deterministic, so every
//! implementation here must realize it *exactly*; the original
//! [`BinaryHeap`]-based queue stays available as [`HeapQueue`] so the
//! property suite can pin the replacement ([`CalendarQueue`])
//! bit-identical against it on whole simulations (see
//! `tests/prop_invariants.rs`).
//!
//! [`CalendarQueue`] is a bucketed calendar: entries hash by
//! `floor(time / width)` into year-indexed buckets held in a
//! `BTreeMap`, so the minimum entry always lives in the first
//! non-empty bucket (the key is monotone in time) and a pop scans just
//! that bucket for its `(time, seq)` minimum. With the adaptive bucket
//! width keeping occupancy at a small constant, pushes and pops touch
//! O(1) entries plus one B-tree probe — which is what lets the 1M-job
//! bench cases stay within a small factor of the 10k-job events/sec
//! rate instead of paying the heap's deep-sift log factor on a
//! million-entry backlog.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};

/// Priority-queue interface of the fleet event loop: entries keyed by
/// `(time, seq)`, popped in ascending `(total_cmp time, seq)` order.
/// `seq` values must be unique per queue (the simulator's monotone
/// counter guarantees it), which makes the order total and every
/// conforming implementation deterministic.
pub trait EventQueue<T> {
    fn push(&mut self, time: f64, seq: u64, item: T);
    /// Remove and return the minimum entry by `(time, seq)`.
    fn pop(&mut self) -> Option<(f64, u64, T)>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Which [`EventQueue`] implementation a run uses
/// ([`super::FleetOptions::event_queue`]). The calendar queue is the
/// default; the heap is kept for the bit-identity equivalence tests
/// and as a fallback.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EventQueueKind {
    /// The pre-scale binary-heap baseline.
    Heap,
    /// Bucketed calendar queue with adaptive width.
    #[default]
    Calendar,
}

impl EventQueueKind {
    pub const ALL: [EventQueueKind; 2] = [EventQueueKind::Heap, EventQueueKind::Calendar];

    pub fn name(&self) -> &'static str {
        match self {
            EventQueueKind::Heap => "heap",
            EventQueueKind::Calendar => "calendar",
        }
    }

    /// Parse a CLI-style name (case-insensitive).
    pub fn parse(s: &str) -> Option<EventQueueKind> {
        match s.to_ascii_lowercase().as_str() {
            "heap" | "binary-heap" => Some(EventQueueKind::Heap),
            "calendar" | "calq" | "bucket" => Some(EventQueueKind::Calendar),
            _ => None,
        }
    }

    /// Construct an empty queue of this kind.
    pub fn make<T: 'static>(&self) -> Box<dyn EventQueue<T>> {
        match self {
            EventQueueKind::Heap => Box::new(HeapQueue::new()),
            EventQueueKind::Calendar => Box::new(CalendarQueue::new()),
        }
    }
}

struct HeapEntry<T> {
    time: f64,
    seq: u64,
    item: T,
}

impl<T> PartialEq for HeapEntry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time.to_bits() == other.time.to_bits() && self.seq == other.seq
    }
}

impl<T> Eq for HeapEntry<T> {}

impl<T> PartialOrd for HeapEntry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for HeapEntry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time.total_cmp(&other.time).then(self.seq.cmp(&other.seq))
    }
}

/// The original binary-heap event queue, kept behind the trait for the
/// calendar-vs-heap equivalence property tests.
pub struct HeapQueue<T> {
    heap: BinaryHeap<Reverse<HeapEntry<T>>>,
}

impl<T> HeapQueue<T> {
    pub fn new() -> Self {
        HeapQueue { heap: BinaryHeap::new() }
    }
}

impl<T> Default for HeapQueue<T> {
    fn default() -> Self {
        HeapQueue::new()
    }
}

impl<T> EventQueue<T> for HeapQueue<T> {
    fn push(&mut self, time: f64, seq: u64, item: T) {
        self.heap.push(Reverse(HeapEntry { time, seq, item }));
    }

    fn pop(&mut self) -> Option<(f64, u64, T)> {
        self.heap.pop().map(|Reverse(e)| (e.time, e.seq, e.item))
    }

    fn len(&self) -> usize {
        self.heap.len()
    }
}

/// Bucket occupancy the adaptive width aims for.
const TARGET_OCCUPANCY: f64 = 4.0;
/// First re-bucketing happens when the queue reaches this size;
/// subsequent ones at every doubling.
const FIRST_RESIZE: usize = 64;

/// Deterministic bucketed calendar queue (see the module docs).
///
/// Entries are unordered within a bucket; a pop scans the first
/// non-empty bucket for its `(time, seq)` minimum, so ordering never
/// depends on insertion layout. Non-finite times are routed to the
/// extreme buckets (`+inf`/NaN last, `-inf` first) and resolved by the
/// same in-bucket scan, so the order matches [`HeapQueue`] on *any*
/// input, not just well-formed simulator times.
pub struct CalendarQueue<T> {
    buckets: BTreeMap<u64, Vec<(f64, u64, T)>>,
    len: usize,
    width: f64,
    /// Next length threshold that triggers a width recomputation.
    resize_at: usize,
}

impl<T> CalendarQueue<T> {
    pub fn new() -> Self {
        CalendarQueue {
            buckets: BTreeMap::new(),
            len: 0,
            width: 1.0,
            resize_at: FIRST_RESIZE,
        }
    }

    /// Bucket key: monotone non-decreasing in `time` under `total_cmp`
    /// (ties within a bucket are resolved by the pop scan).
    fn key(&self, time: f64) -> u64 {
        if time.is_finite() {
            let q = (time / self.width).floor();
            if q <= 0.0 {
                0
            } else {
                q as u64 // saturates at u64::MAX for huge quotients
            }
        } else if time == f64::NEG_INFINITY {
            0
        } else {
            u64::MAX // +inf and NaN: last bucket, ordered by the scan
        }
    }

    /// Recompute the width from the observed span so average occupancy
    /// stays near [`TARGET_OCCUPANCY`], then re-bucket everything.
    /// O(n), triggered at geometric length thresholds — amortized O(1)
    /// per push.
    fn rebucket(&mut self) {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        let mut finite = 0usize;
        for bucket in self.buckets.values() {
            for &(t, _, _) in bucket {
                if t.is_finite() {
                    lo = lo.min(t);
                    hi = hi.max(t);
                    finite += 1;
                }
            }
        }
        if finite >= 2 && hi > lo {
            self.width = ((hi - lo) / finite as f64 * TARGET_OCCUPANCY).max(1e-9);
        }
        let old = std::mem::take(&mut self.buckets);
        for (_, bucket) in old {
            for (t, s, item) in bucket {
                let k = self.key(t);
                self.buckets.entry(k).or_default().push((t, s, item));
            }
        }
    }
}

impl<T> Default for CalendarQueue<T> {
    fn default() -> Self {
        CalendarQueue::new()
    }
}

impl<T> EventQueue<T> for CalendarQueue<T> {
    fn push(&mut self, time: f64, seq: u64, item: T) {
        let k = self.key(time);
        self.buckets.entry(k).or_default().push((time, seq, item));
        self.len += 1;
        if self.len >= self.resize_at {
            self.rebucket();
            self.resize_at = self.resize_at.saturating_mul(2);
        }
    }

    fn pop(&mut self) -> Option<(f64, u64, T)> {
        let mut entry = self.buckets.first_entry()?;
        let bucket = entry.get_mut();
        let best = bucket
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
            .map(|(i, _)| i)?;
        let out = bucket.swap_remove(best);
        if bucket.is_empty() {
            entry.remove();
        }
        self.len -= 1;
        Some(out)
    }

    fn len(&self) -> usize {
        self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-random f64 stream (no external RNG).
    fn lcg_times(n: usize, seed: u64) -> Vec<f64> {
        let mut x = seed | 1;
        (0..n)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((x >> 11) % 1_000_000) as f64 / 7.0
            })
            .collect()
    }

    fn drain<T>(q: &mut dyn EventQueue<T>) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        while let Some((t, s, _)) = q.pop() {
            out.push((t, s));
        }
        out
    }

    #[test]
    fn calendar_matches_heap_on_bulk_load() {
        let times = lcg_times(5000, 42);
        let mut cal = CalendarQueue::new();
        let mut heap = HeapQueue::new();
        for (i, &t) in times.iter().enumerate() {
            cal.push(t, i as u64, i);
            heap.push(t, i as u64, i);
        }
        assert_eq!(cal.len(), heap.len());
        assert_eq!(drain(&mut cal), drain(&mut heap));
        assert!(cal.is_empty() && heap.is_empty());
    }

    #[test]
    fn calendar_matches_heap_interleaved() {
        // push/pop interleaving with monotone-ish times, like the sim
        let times = lcg_times(2000, 7);
        let mut cal: CalendarQueue<usize> = CalendarQueue::new();
        let mut heap: HeapQueue<usize> = HeapQueue::new();
        let mut seq = 0u64;
        let mut clock = 0.0f64;
        for chunk in times.chunks(10) {
            for &t in chunk {
                cal.push(clock + t, seq, 0);
                heap.push(clock + t, seq, 0);
                seq += 1;
            }
            for _ in 0..7 {
                let a = cal.pop().map(|(t, s, _)| (t, s));
                let b = heap.pop().map(|(t, s, _)| (t, s));
                assert_eq!(a, b);
                if let Some((t, _)) = a {
                    clock = clock.max(t);
                }
            }
        }
        assert_eq!(drain(&mut cal), drain(&mut heap));
    }

    #[test]
    fn equal_times_pop_in_sequence_order() {
        let mut cal = CalendarQueue::new();
        for s in [5u64, 1, 3, 2, 4] {
            cal.push(100.0, s, ());
        }
        let seqs: Vec<u64> = drain(&mut cal).into_iter().map(|(_, s)| s).collect();
        assert_eq!(seqs, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn extreme_times_sort_like_total_cmp() {
        let mut cal = CalendarQueue::new();
        let mut heap = HeapQueue::new();
        for (i, t) in [1.0, f64::INFINITY, 0.0, f64::NEG_INFINITY, f64::NAN, 1e308, -1e308]
            .into_iter()
            .enumerate()
        {
            cal.push(t, i as u64, ());
            heap.push(t, i as u64, ());
        }
        let a: Vec<u64> = drain(&mut cal).into_iter().map(|(_, s)| s).collect();
        let b: Vec<u64> = drain(&mut heap).into_iter().map(|(_, s)| s).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn resize_preserves_order() {
        // enough entries to cross several resize thresholds
        let times = lcg_times(1000, 99);
        let mut cal = CalendarQueue::new();
        for (i, &t) in times.iter().enumerate() {
            cal.push(t * 1e4, i as u64, ());
        }
        let popped = drain(&mut cal);
        let mut sorted = popped.clone();
        sorted.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        assert_eq!(popped, sorted);
    }

    #[test]
    fn kind_parses_and_constructs() {
        assert_eq!(EventQueueKind::parse("heap"), Some(EventQueueKind::Heap));
        assert_eq!(EventQueueKind::parse("CALENDAR"), Some(EventQueueKind::Calendar));
        assert_eq!(EventQueueKind::parse("fibonacci"), None);
        assert_eq!(EventQueueKind::default(), EventQueueKind::Calendar);
        for kind in EventQueueKind::ALL {
            let mut q = kind.make::<u32>();
            q.push(1.0, 0, 9);
            assert_eq!(q.pop(), Some((1.0, 0, 9)));
        }
    }
}
