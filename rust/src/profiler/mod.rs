//! The PAC+ profiler (paper §V-A "Profiling", workflow Step 3).
//!
//! Produces the per-(device, layer, batch) FP/BP time tables
//! `t_f^{d,l}(β)` / `t_b^{d,l}(β)` and the memory terms the planner
//! consumes. On the paper's testbed these come from running a calibration
//! dataset on the physical boards; here they come from the calibrated
//! device performance models (DESIGN.md §2) — the planner is agnostic to
//! the source, and [`Profile::from_measurements`] lets the real runtime
//! substitute measured times.

use crate::cluster::Device;
use crate::model::graph::LayerGraph;
use crate::model::{Method, Precision, Workload};

/// FP/BP time tables + memory model for one (model, method, precision).
#[derive(Debug, Clone)]
pub struct Profile {
    pub graph: LayerGraph,
    pub method: Method,
    pub precision: Precision,
    pub seq: usize,
    /// Dequantization overhead on compute when the backbone is stored in
    /// an integer format (dequant-to-f32 on the fly, §IV-D).
    pub dequant_overhead: f64,
    /// Optional measured per-(device-id, block) forward/backward times at
    /// batch 1, overriding the analytic model (filled by the runtime).
    measured: Option<MeasuredTimes>,
}

#[derive(Debug, Clone)]
struct MeasuredTimes {
    /// t_f[device_id][block] at batch 1, seconds.
    fwd: Vec<Vec<f64>>,
    bwd: Vec<Vec<f64>>,
}

impl Profile {
    pub fn new(graph: LayerGraph, method: Method, precision: Precision, seq: usize) -> Profile {
        let dequant_overhead = match precision {
            Precision::FP32 | Precision::FP16 => 1.0,
            Precision::INT8 | Precision::INT4 => 1.05,
        };
        Profile { graph, method, precision, seq, dequant_overhead, measured: None }
    }

    /// Build a profile from real measured per-block batch-1 times
    /// (device-id indexed). Times for batch β scale linearly.
    pub fn from_measurements(
        graph: LayerGraph,
        method: Method,
        precision: Precision,
        seq: usize,
        fwd: Vec<Vec<f64>>,
        bwd: Vec<Vec<f64>>,
    ) -> Profile {
        let mut p = Profile::new(graph, method, precision, seq);
        p.measured = Some(MeasuredTimes { fwd, bwd });
        p
    }

    /// Forward time of block `l` on device `d` with micro-batch β
    /// (the paper's `t_f^{d,l}(β)`).
    pub fn t_f(&self, d: &Device, l: usize, beta: usize) -> f64 {
        if beta == 0 {
            return 0.0;
        }
        if let Some(m) = &self.measured {
            return m.fwd[d.id][l] * beta as f64;
        }
        let tokens = (beta * self.seq) as u64;
        let flops = self.graph.block_flops_fwd(l, tokens, self.seq)
            + self.graph.block_adapter_flops(l, self.method, tokens, self.seq) / 3.0;
        d.compute_time(flops * self.dequant_overhead)
    }

    /// Backward time of block `l` on device `d` with micro-batch β
    /// (`t_b^{d,l}(β)`). Zero backbone backward for Parallel Adapters.
    pub fn t_b(&self, d: &Device, l: usize, beta: usize) -> f64 {
        if beta == 0 {
            return 0.0;
        }
        if let Some(m) = &self.measured {
            return m.bwd[d.id][l] * beta as f64;
        }
        let tokens = (beta * self.seq) as u64;
        let flops = self.graph.block_flops_bwd(l, self.method, tokens, self.seq)
            + self.graph.block_adapter_flops(l, self.method, tokens, self.seq) * 2.0 / 3.0;
        if flops == 0.0 {
            return 0.0;
        }
        d.compute_time(flops * self.dequant_overhead)
    }

    /// Combined FP+BP time of a span of blocks (used by Eq. 4's inner term).
    pub fn span_time(&self, d: &Device, x: usize, y: usize, beta: usize) -> f64 {
        (x..y).map(|l| self.t_f(d, l, beta) + self.t_b(d, l, beta)).sum()
    }

    /// Peak memory of a device hosting blocks `[x, y)` with `in_flight`
    /// micro-batches of size β resident (1F1B holds several) — the
    /// paper's `m_d` = parameters + gradients (+opt) + activations.
    pub fn span_mem_bytes(&self, x: usize, y: usize, beta: usize, in_flight: usize) -> u64 {
        let weights = self.graph.span_weight_bytes(x, y, self.precision);
        let trainable = self.graph.span_trainable_bytes(x, y, self.method);
        // Full FT: gradient buffers only (plain SGD — Table I calibration);
        // PEFT: fp32 trainable copy + grads + 2 Adam states.
        let train_state = match self.method {
            Method::FullFT => trainable,
            _ => 4 * trainable,
        };
        let wl = Workload::new(beta, self.seq);
        let act: u64 = (x..y)
            .map(|l| self.graph.block_act_bytes(l, self.method, wl))
            .sum::<u64>()
            * in_flight.max(1) as u64;
        weights + train_state + act
    }

    /// Forward-direction bytes crossing the boundary after block `y-1`.
    pub fn boundary_bytes_fwd(&self, beta: usize) -> u64 {
        crate::model::cost::stage_boundary_bytes(
            &self.graph.spec,
            self.method,
            Workload::new(beta, self.seq),
        )
    }

    /// Backward-direction boundary bytes (activation gradients). Zero for
    /// Parallel Adapters backbone boundaries except the adapter state
    /// gradient (width d/r).
    pub fn boundary_bytes_bwd(&self, beta: usize) -> u64 {
        let tokens = (beta * self.seq) as u64;
        match self.method {
            Method::ParallelAdapters { .. } => {
                tokens * self.graph.spec.d_adapter() as u64 * 4
            }
            _ => tokens * self.graph.spec.d_model as u64 * 4,
        }
    }

    /// Bytes AllReduced by a stage hosting `[x, y)` after each mini-batch.
    pub fn allreduce_bytes(&self, x: usize, y: usize) -> u64 {
        self.graph.span_trainable_bytes(x, y, self.method)
    }

    /// Build O(1) span-query tables for the planner's inner loops
    /// (EXPERIMENTS.md §Perf: this turned the Eq. 3/Eq. 4 DPs from O(L)
    /// per span probe into prefix-sum lookups).
    pub fn span_costs(&self) -> SpanCosts {
        let l = self.graph.len();
        let mut fwd = vec![0.0f64; l + 1]; // per-sample fwd FLOPs (w/ adapter share)
        let mut bwd = vec![0.0f64; l + 1];
        let mut weights = vec![0u64; l + 1];
        let mut train_state = vec![0u64; l + 1];
        let mut act1 = vec![0u64; l + 1]; // act bytes per sample
        let wl1 = Workload::new(1, self.seq);
        let tokens1 = self.seq as u64;
        for i in 0..l {
            let adapter = self.graph.block_adapter_flops(i, self.method, tokens1, self.seq);
            fwd[i + 1] = fwd[i]
                + (self.graph.block_flops_fwd(i, tokens1, self.seq) + adapter / 3.0)
                    * self.dequant_overhead;
            bwd[i + 1] = bwd[i]
                + (self.graph.block_flops_bwd(i, self.method, tokens1, self.seq)
                    + adapter * 2.0 / 3.0)
                    * self.dequant_overhead;
            weights[i + 1] = weights[i] + self.graph.span_weight_bytes(i, i + 1, self.precision);
            let t = self.graph.span_trainable_bytes(i, i + 1, self.method);
            train_state[i + 1] = train_state[i]
                + match self.method {
                    Method::FullFT => t,
                    _ => 4 * t,
                };
            act1[i + 1] = act1[i] + self.graph.block_act_bytes(i, self.method, wl1);
        }
        let measured = self.measured.as_ref().map(|m| {
            let pref = |rows: &Vec<Vec<f64>>| {
                rows.iter()
                    .map(|r| {
                        let mut p = vec![0.0; r.len() + 1];
                        for (i, v) in r.iter().enumerate() {
                            p[i + 1] = p[i] + v;
                        }
                        p
                    })
                    .collect::<Vec<_>>()
            };
            (pref(&m.fwd), pref(&m.bwd))
        });
        SpanCosts { fwd, bwd, weights, train_state, act1, measured }
    }
}

/// Prefix-sum span cost tables (see [`Profile::span_costs`]).
#[derive(Debug, Clone)]
pub struct SpanCosts {
    fwd: Vec<f64>,
    bwd: Vec<f64>,
    weights: Vec<u64>,
    train_state: Vec<u64>,
    act1: Vec<u64>,
    measured: Option<(Vec<Vec<f64>>, Vec<Vec<f64>>)>,
}

impl SpanCosts {
    const LAUNCH_OVERHEAD: f64 = 150e-6;

    /// Forward time of blocks [x, y) on `d` with micro-batch β.
    pub fn t_f(&self, d: &Device, x: usize, y: usize, beta: usize) -> f64 {
        if beta == 0 || y <= x {
            return 0.0;
        }
        if let Some((fwd, _)) = &self.measured {
            return (fwd[d.id][y] - fwd[d.id][x]) * beta as f64;
        }
        (self.fwd[y] - self.fwd[x]) * beta as f64 / d.kind.effective_flops()
            + (y - x) as f64 * Self::LAUNCH_OVERHEAD
    }

    /// Backward time of blocks [x, y) on `d` with micro-batch β.
    pub fn t_b(&self, d: &Device, x: usize, y: usize, beta: usize) -> f64 {
        if beta == 0 || y <= x {
            return 0.0;
        }
        if let Some((_, bwd)) = &self.measured {
            return (bwd[d.id][y] - bwd[d.id][x]) * beta as f64;
        }
        let flops = (self.bwd[y] - self.bwd[x]) * beta as f64;
        if flops == 0.0 {
            return 0.0;
        }
        flops / d.kind.effective_flops() + (y - x) as f64 * Self::LAUNCH_OVERHEAD
    }

    pub fn span_time(&self, d: &Device, x: usize, y: usize, beta: usize) -> f64 {
        self.t_f(d, x, y, beta) + self.t_b(d, x, y, beta)
    }

    pub fn span_mem(&self, x: usize, y: usize, beta: usize, in_flight: usize) -> u64 {
        self.weights[y] - self.weights[x] + (self.train_state[y] - self.train_state[x])
            + (self.act1[y] - self.act1[x]) * beta as u64 * in_flight.max(1) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::DeviceKind;
    use crate::model::ModelSpec;

    fn profile(method: Method) -> Profile {
        Profile::new(
            LayerGraph::new(ModelSpec::t5_base()),
            method,
            Precision::FP32,
            128,
        )
    }

    #[test]
    fn times_scale_with_batch() {
        let p = profile(Method::FullFT);
        let d = Device::new(0, DeviceKind::NanoH);
        let t1 = p.t_f(&d, 1, 1);
        let t4 = p.t_f(&d, 1, 4);
        assert!(t4 > 3.0 * t1 && t4 < 4.5 * t1, "{t1} {t4}");
    }

    #[test]
    fn faster_device_is_faster() {
        let p = profile(Method::FullFT);
        let nano = Device::new(0, DeviceKind::NanoH);
        let tx2 = Device::new(1, DeviceKind::Tx2H);
        assert!(p.t_f(&tx2, 1, 4) < p.t_f(&nano, 1, 4));
    }

    #[test]
    fn pa_backbone_bwd_is_adapter_only() {
        let p = profile(Method::pa(false));
        let d = Device::new(0, DeviceKind::NanoH);
        let full = profile(Method::FullFT);
        assert!(p.t_b(&d, 1, 4) < 0.3 * full.t_b(&d, 1, 4));
    }

    #[test]
    fn zero_batch_zero_time() {
        let p = profile(Method::FullFT);
        let d = Device::new(0, DeviceKind::NanoH);
        assert_eq!(p.t_f(&d, 1, 0), 0.0);
        assert_eq!(p.t_b(&d, 1, 0), 0.0);
    }

    #[test]
    fn memory_grows_with_inflight() {
        let p = profile(Method::FullFT);
        let m1 = p.span_mem_bytes(0, 5, 4, 1);
        let m4 = p.span_mem_bytes(0, 5, 4, 4);
        assert!(m4 > m1);
    }

    #[test]
    fn t5_large_full_oversubscribes_nano() {
        // the root cause of Table V's OOM column: even one device's share
        // of T5-Large full-FT exceeds a Nano's budget when hosting the
        // whole model
        let p = Profile::new(
            LayerGraph::new(ModelSpec::t5_large()),
            Method::FullFT,
            Precision::FP32,
            128,
        );
        let whole = p.span_mem_bytes(0, p.graph.len(), 16, 1);
        assert!(whole > DeviceKind::NanoH.mem_budget());
    }

    #[test]
    fn measured_profile_overrides() {
        let g = LayerGraph::new(ModelSpec::tiny());
        let n = g.len();
        let fwd = vec![vec![0.5; n]];
        let bwd = vec![vec![1.0; n]];
        let p = Profile::from_measurements(
            g, Method::pa(false), Precision::FP32, 16, fwd, bwd);
        let d = Device::new(0, DeviceKind::NanoH);
        assert_eq!(p.t_f(&d, 0, 2), 1.0);
        assert_eq!(p.t_b(&d, 3, 1), 1.0);
    }

    #[test]
    fn int8_adds_dequant_overhead() {
        let g = LayerGraph::new(ModelSpec::t5_base());
        let f32p = Profile::new(g.clone(), Method::pa(false), Precision::FP32, 128);
        let i8p = Profile::new(g, Method::pa(false), Precision::INT8, 128);
        let d = Device::new(0, DeviceKind::NanoH);
        assert!(i8p.t_f(&d, 1, 4) > f32p.t_f(&d, 1, 4));
    }
}
