//! The PAC+ activation cache (paper §IV-B, Fig. 11).
//!
//! Because the backbone is frozen, its per-layer activations for a given
//! input sequence are invariant across epochs; caching them removes the
//! backbone forward pass from every epoch after the first. This module is
//! the *real* cache used by the execution engine (`exec`): a disk-backed
//! store of f32 activation slabs keyed by sample id, with an in-memory
//! index, capacity accounting, and integrity checks.

use std::fs::{self, File};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Disk-backed store of per-sample activation slabs.
///
/// Each entry is the stacked backbone activation `[L+1, S, D]` for one
/// sample, stored as little-endian f32 — exactly the per-sample slice of
/// the `acts` tensor the AOT `backbone_fwd` artifact produces.
pub struct ActivationCache {
    dir: PathBuf,
    /// Floats per entry (= (L+1)·S·D).
    entry_len: usize,
    /// Present sample ids (dense bitmap).
    present: Vec<bool>,
    bytes_written: u64,
}

impl ActivationCache {
    /// Open (or create) a cache directory sized for `capacity` samples of
    /// `entry_len` floats each.
    pub fn open(dir: impl AsRef<Path>, capacity: usize, entry_len: usize) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        let mut cache = ActivationCache {
            dir,
            entry_len,
            present: vec![false; capacity],
            bytes_written: 0,
        };
        // recover any entries already on disk (resume support)
        for id in 0..capacity {
            let p = cache.path(id);
            if let Ok(md) = fs::metadata(&p) {
                if md.len() == (entry_len * 4) as u64 {
                    cache.present[id] = true;
                }
            }
        }
        Ok(cache)
    }

    fn path(&self, id: usize) -> PathBuf {
        self.dir.join(format!("act_{id:08}.bin"))
    }

    pub fn capacity(&self) -> usize {
        self.present.len()
    }

    pub fn entry_len(&self) -> usize {
        self.entry_len
    }

    /// Number of cached samples.
    pub fn len(&self) -> usize {
        self.present.iter().filter(|&&p| p).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn contains(&self, id: usize) -> bool {
        self.present.get(id).copied().unwrap_or(false)
    }

    /// Whether every sample id in `[0, capacity)` is cached — the
    /// condition for entering the phase-2 (backbone-free) epochs.
    pub fn is_complete(&self) -> bool {
        self.present.iter().all(|&p| p)
    }

    /// Store one sample's activation slab.
    pub fn put(&mut self, id: usize, acts: &[f32]) -> Result<()> {
        if id >= self.capacity() {
            bail!("sample id {id} out of capacity {}", self.capacity());
        }
        if acts.len() != self.entry_len {
            bail!("entry length {} != expected {}", acts.len(), self.entry_len);
        }
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(acts.as_ptr() as *const u8, acts.len() * 4)
        };
        let tmp = self.dir.join(format!(".tmp_{id:08}"));
        {
            let mut f = File::create(&tmp)?;
            f.write_all(bytes)?;
        }
        fs::rename(&tmp, self.path(id))?; // atomic publish
        self.present[id] = true;
        self.bytes_written += bytes.len() as u64;
        Ok(())
    }

    /// Load one sample's slab.
    pub fn get(&self, id: usize) -> Result<Vec<f32>> {
        if !self.contains(id) {
            bail!("sample {id} not cached");
        }
        let mut f = File::open(self.path(id))?;
        let mut out = vec![0f32; self.entry_len];
        // read straight into the f32 buffer (little-endian hosts; the
        // per-element from_le_bytes loop cost ~10x this — §Perf)
        #[cfg(target_endian = "little")]
        {
            let bytes: &mut [u8] = unsafe {
                std::slice::from_raw_parts_mut(
                    out.as_mut_ptr() as *mut u8,
                    out.len() * 4,
                )
            };
            f.read_exact(bytes)?;
        }
        #[cfg(not(target_endian = "little"))]
        {
            let mut buf = vec![0u8; self.entry_len * 4];
            f.read_exact(&mut buf)?;
            for (i, chunk) in buf.chunks_exact(4).enumerate() {
                out[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            }
        }
        Ok(out)
    }

    /// Load a batch of samples concatenated (micro-batch assembly for the
    /// `adapter_step` artifact). Order is preserved.
    pub fn get_batch(&self, ids: &[usize]) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(ids.len() * self.entry_len);
        for &id in ids {
            out.extend_from_slice(&self.get(id)?);
        }
        Ok(out)
    }

    /// Total bytes currently on disk.
    pub fn disk_bytes(&self) -> u64 {
        self.len() as u64 * self.entry_len as u64 * 4
    }

    /// Remove every entry (paper §V-B: "the cache will be cleared once
    /// the fine-tuning process finishes").
    pub fn clear(&mut self) -> Result<()> {
        for id in 0..self.capacity() {
            if self.present[id] {
                let _ = fs::remove_file(self.path(id));
                self.present[id] = false;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("pacpp_cache_test_{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn put_get_roundtrip() {
        let dir = tmpdir("roundtrip");
        let mut c = ActivationCache::open(&dir, 4, 8).unwrap();
        let data: Vec<f32> = (0..8).map(|i| i as f32 * 0.5).collect();
        c.put(2, &data).unwrap();
        assert!(c.contains(2));
        assert!(!c.contains(1));
        assert_eq!(c.get(2).unwrap(), data);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_bad_shapes() {
        let dir = tmpdir("badshape");
        let mut c = ActivationCache::open(&dir, 2, 8).unwrap();
        assert!(c.put(0, &[1.0; 7]).is_err());
        assert!(c.put(5, &[1.0; 8]).is_err());
        assert!(c.get(0).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn completeness_tracking() {
        let dir = tmpdir("complete");
        let mut c = ActivationCache::open(&dir, 3, 4).unwrap();
        assert!(!c.is_complete());
        for id in 0..3 {
            c.put(id, &[id as f32; 4]).unwrap();
        }
        assert!(c.is_complete());
        assert_eq!(c.len(), 3);
        assert_eq!(c.disk_bytes(), 3 * 4 * 4);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn batch_assembly_preserves_order() {
        let dir = tmpdir("batch");
        let mut c = ActivationCache::open(&dir, 4, 2).unwrap();
        for id in 0..4 {
            c.put(id, &[id as f32, id as f32 + 0.5]).unwrap();
        }
        let b = c.get_batch(&[3, 0, 2]).unwrap();
        assert_eq!(b, vec![3.0, 3.5, 0.0, 0.5, 2.0, 2.5]);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reopen_recovers_entries() {
        let dir = tmpdir("reopen");
        {
            let mut c = ActivationCache::open(&dir, 2, 4).unwrap();
            c.put(1, &[9.0; 4]).unwrap();
        }
        let c2 = ActivationCache::open(&dir, 2, 4).unwrap();
        assert!(c2.contains(1));
        assert!(!c2.contains(0));
        assert_eq!(c2.get(1).unwrap(), vec![9.0; 4]);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn clear_empties_cache() {
        let dir = tmpdir("clear");
        let mut c = ActivationCache::open(&dir, 2, 4).unwrap();
        c.put(0, &[1.0; 4]).unwrap();
        c.clear().unwrap();
        assert!(c.is_empty());
        assert!(!c.path(0).exists());
        fs::remove_dir_all(&dir).unwrap();
    }
}
