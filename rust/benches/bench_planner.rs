//! Planner performance benchmarks (DESIGN.md §8 L3 target: < 1 s for
//! L=50 blocks, |D|=8, B=16 — the paper reports "several minutes on an
//! edge device" for the same O(B·L²·|D|³) DP).
//!
//! Includes `bench_strategy_search`: serial vs threaded evaluation of
//! the σ (stage-count) candidates (`PlannerOptions::search_threads`) —
//! the two must return bit-identical plans, so only wall-clock differs.
//!
//! Run: `cargo bench --bench bench_planner`

use pacpp::cluster::Env;
use pacpp::model::graph::LayerGraph;
use pacpp::model::{Method, ModelSpec, Precision};
use pacpp::planner::{plan, PlannerOptions};
use pacpp::profiler::Profile;
use pacpp::sched::simulate_minibatch;
use pacpp::util::bench::Bench;

fn main() {
    let mut b = Bench::new("planner");

    for (name, spec) in [
        ("t5-base", ModelSpec::t5_base()),
        ("t5-large", ModelSpec::t5_large()),
    ] {
        for n in [4usize, 8] {
            let profile = Profile::new(
                LayerGraph::new(spec.clone()),
                Method::pa(false),
                Precision::FP32,
                128,
            );
            let env = Env::nanos(n);
            let opts = PlannerOptions {
                microbatch: 4,
                n_microbatches: 4,
                ..Default::default()
            };
            b.run(&format!("plan/{name}/{n}dev/B4"), || {
                plan(&profile, &env, &opts).unwrap()
            });
        }
    }

    // heterogeneous planning (Eq. 4 dispatch DP dominates)
    {
        let profile = Profile::new(
            LayerGraph::new(ModelSpec::t5_large()),
            Method::pa(false),
            Precision::FP32,
            128,
        );
        let env = Env::env_b();
        for bsz in [4usize, 16] {
            let opts = PlannerOptions {
                microbatch: bsz,
                n_microbatches: 4,
                ..Default::default()
            };
            b.run(&format!("plan/t5-large/env_b/B{bsz}"), || {
                plan(&profile, &env, &opts).unwrap()
            });
        }
    }

    // bench_strategy_search: serial vs threaded σ-candidate evaluation.
    // Eight devices give eight candidate stage counts — enough to keep a
    // small worker pool busy; the selected plan is identical either way.
    {
        let profile = Profile::new(
            LayerGraph::new(ModelSpec::t5_large()),
            Method::pa(false),
            Precision::FP32,
            128,
        );
        let env = Env::nanos(8);
        let base = PlannerOptions { microbatch: 4, n_microbatches: 4, ..Default::default() };
        let serial_opts = PlannerOptions { search_threads: Some(1), ..base.clone() };
        let threaded_opts = PlannerOptions { search_threads: None, ..base };
        let serial = b
            .run("bench_strategy_search/serial/t5-large/8dev", || {
                plan(&profile, &env, &serial_opts).unwrap()
            })
            .map(|r| r.summary.mean);
        let threaded = b
            .run("bench_strategy_search/threaded/t5-large/8dev", || {
                plan(&profile, &env, &threaded_opts).unwrap()
            })
            .map(|r| r.summary.mean);
        if let (Some(s), Some(t)) = (serial, threaded) {
            let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            println!(
                "\nsigma-search speedup (serial/threaded): {:.2}x on {cores} cores",
                s / t
            );
        }
    }

    // 1F1B event simulation
    {
        let profile = Profile::new(
            LayerGraph::new(ModelSpec::t5_large()),
            Method::pa(false),
            Precision::FP32,
            128,
        );
        let env = Env::nanos(8);
        let opts = PlannerOptions {
            microbatch: 4,
            n_microbatches: 8,
            ..Default::default()
        };
        let p = plan(&profile, &env, &opts).unwrap();
        b.run("simulate/t5-large/8dev/M8", || {
            simulate_minibatch(&p, &profile, &env.network)
        });
    }

    // paper target check: planning must be far under the paper's
    // "several minutes"
    let slowest = b
        .results()
        .iter()
        .filter(|r| r.name.starts_with("plan/"))
        .map(|r| r.summary.mean)
        .fold(0.0f64, f64::max);
    println!(
        "\nslowest planning case: {:.3} s (target < 1 s, paper: minutes on a Nano)",
        slowest
    );
    assert!(slowest < 1.0, "planner regression: {slowest} s");
}
