//! Fleet event-loop throughput benches. Usage:
//!
//! ```bash
//! cargo bench --bench bench_fleet            # all cases
//! cargo bench --bench bench_fleet -- 10k     # just the 10k-job case
//! ```
//!
//! The `fleet_event_loop_*` cases measure the discrete-event core
//! (calendar event queue, incremental dispatch, accounting) on a
//! stream of uniform jobs — one planner call total thanks to the
//! oracle's shape memo — and report derived events/sec and jobs/sec
//! next to the wall-clock summary. The 100k/1m scale cases are the
//! scaling gate: their events/sec should stay within an order of
//! magnitude of the 10k case. The `_churn` case layers a churn trace
//! on top, adding the replan/restart paths to the measured loop. The
//! `_traced` case re-runs the 100k stream with a fully-enabled
//! `Observer` and prints the tracing overhead (the untraced 100k case
//! doubles as the disabled-observer "costs nothing" gate).

use pacpp::cluster::Env;
use pacpp::fleet::{
    generate_churn, simulate_fleet, simulate_fleet_observed, simulate_fleet_with, BestFit,
    CheckpointSpec, FleetOptions, Job, PreemptReplan,
};
use pacpp::learn::{LearnedQueue, Mlp, N_FEATURES};
use pacpp::model::ModelSpec;
use pacpp::obs::{Observer, DEFAULT_TRACE_CAPACITY};
use pacpp::util::bench::Bench;
use pacpp::util::rng::Rng;

/// `n` identical small jobs, one arrival every 30 s: the oracle
/// memoizes their shape once, so the bench times the event loop, not
/// the planner.
fn uniform_jobs(n: usize) -> Vec<Job> {
    (0..n)
        .map(|i| Job::new(i, i as f64 * 30.0, ModelSpec::t5_base(), 512, 2))
        .collect()
}

/// Horizon long enough that every job in every case completes (the
/// jobs/sec figure is then jobs-completed per wall-clock second).
fn opts() -> FleetOptions {
    FleetOptions { horizon: 1e9, ..Default::default() }
}

fn main() {
    let mut b = Bench::new("fleet");
    let env = Env::nanos(8);

    for n in [1_000usize, 10_000] {
        let name = format!("fleet_event_loop_{}k_jobs", n / 1_000);
        if !b.enabled(&name) {
            continue;
        }
        let jobs = uniform_jobs(n);
        let m = simulate_fleet(&env, &jobs, &[], &BestFit, &opts()).unwrap();
        assert_eq!(m.completed, n, "bench jobs must all complete");
        let res = b
            .run(&name, || simulate_fleet(&env, &jobs, &[], &BestFit, &opts()).unwrap())
            .cloned();
        if let Some(r) = res {
            println!(
                "    -> {:.0} events/sec, {:.0} jobs/sec ({} events, {} jobs)",
                m.events as f64 / r.summary.mean,
                m.completed as f64 / r.summary.mean,
                m.events,
                m.completed
            );
        }
    }

    // Scale cases: the same uniform stream at 100k and 1M jobs. The
    // events/sec figure here against the 10k case is the scaling
    // acceptance gate — the calendar queue and incremental dispatch
    // keep per-event cost flat as the backlog grows. The horizon is
    // widened so the tail drains even if arrivals outpace service.
    let mut base_100k_mean: Option<f64> = None;
    for n in [100_000usize, 1_000_000] {
        let name = if n >= 1_000_000 {
            format!("fleet_event_loop_{}m_jobs", n / 1_000_000)
        } else {
            format!("fleet_event_loop_{}k_jobs", n / 1_000)
        };
        if !b.enabled(&name) {
            continue;
        }
        let jobs = uniform_jobs(n);
        let scale_opts = FleetOptions { horizon: 1e10, ..Default::default() };
        let m = simulate_fleet(&env, &jobs, &[], &BestFit, &scale_opts).unwrap();
        assert_eq!(m.completed, n, "scale-bench jobs must all complete");
        let res = b
            .run(&name, || {
                simulate_fleet(&env, &jobs, &[], &BestFit, &scale_opts).unwrap()
            })
            .cloned();
        if let Some(r) = res {
            println!(
                "    -> {:.0} events/sec, {:.0} jobs/sec ({} events, {} jobs)",
                m.events as f64 / r.summary.mean,
                m.completed as f64 / r.summary.mean,
                m.events,
                m.completed
            );
            if n == 100_000 {
                base_100k_mean = Some(r.summary.mean);
            }
        }
    }

    // Observability gate. `fleet_event_loop_100k_jobs` above *is* the
    // disabled-`Observer` path (every `simulate_fleet` call routes
    // through the observed entry point with a disabled observer), so
    // its events/sec holding steady is the "tracing off costs nothing"
    // acceptance check. This companion re-times the same 100k stream
    // with a fully-enabled observer (sample = 1, default ring) and
    // prints the overhead `--trace-out` actually buys.
    if b.enabled("fleet_event_loop_100k_jobs_traced") {
        let jobs = uniform_jobs(100_000);
        let scale_opts = FleetOptions { horizon: 1e10, ..Default::default() };
        let m = simulate_fleet(&env, &jobs, &[], &BestFit, &scale_opts).unwrap();
        let res = b
            .run("fleet_event_loop_100k_jobs_traced", || {
                let obs = Observer::with(1, DEFAULT_TRACE_CAPACITY);
                simulate_fleet_observed(&env, &jobs, &[], &BestFit, &scale_opts, &obs).unwrap()
            })
            .cloned();
        if let Some(r) = res {
            println!(
                "    -> {:.0} events/sec ({} events, sample=1)",
                m.events as f64 / r.summary.mean,
                m.events
            );
            if let Some(base) = base_100k_mean {
                println!(
                    "    -> enabled-observer overhead vs disabled path: {:+.1}%",
                    (r.summary.mean / base - 1.0) * 100.0
                );
            }
        }
    }

    if b.enabled("fleet_event_loop_churn_1k_jobs") {
        let jobs = uniform_jobs(1_000);
        // dense churn across the run's active window (arrivals end at
        // 30 ks; the backlog drains within ~100 ks)
        let churn = generate_churn(&env, 100_000.0, 20.0, 7);
        let m = simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &opts()).unwrap();
        let res = b
            .run("fleet_event_loop_churn_1k_jobs", || {
                simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &opts()).unwrap()
            })
            .cloned();
        if let Some(r) = res {
            println!(
                "    -> {:.0} events/sec ({} events, {} completed, {} replans, {} restarts)",
                m.events as f64 / r.summary.mean,
                m.events,
                m.completed,
                m.replans,
                m.restarts
            );
        }
    }

    // The PR-4 paths: EASY-backfill's shadow/backfill scan plus
    // checkpointed restarts, under the same dense churn — measures the
    // queue-policy overhead the FIFO cases never exercise.
    if b.enabled("fleet_event_loop_backfill_ckpt_1k_jobs") {
        let jobs = uniform_jobs(1_000);
        let churn = generate_churn(&env, 100_000.0, 20.0, 7);
        let bc_opts = FleetOptions {
            queue: "backfill".into(),
            ckpt: Some(CheckpointSpec::new(2, 60.0)),
            ..opts()
        };
        let m = simulate_fleet(&env, &jobs, &churn, &BestFit, &bc_opts).unwrap();
        let res = b
            .run("fleet_event_loop_backfill_ckpt_1k_jobs", || {
                simulate_fleet(&env, &jobs, &churn, &BestFit, &bc_opts).unwrap()
            })
            .cloned();
        if let Some(r) = res {
            println!(
                "    -> {:.0} events/sec ({} events, {} completed, {} restarts, \
                 {} ckpts, {:.0} s ckpt overhead)",
                m.events as f64 / r.summary.mean,
                m.events,
                m.completed,
                m.restarts,
                m.ckpt_count,
                m.ckpt_overhead
            );
        }
    }

    // The learned-discipline inference path: per dispatch, featurize
    // every placeable candidate and run one MLP forward each. The
    // weights are seeded-random — inference cost does not depend on
    // training — so this times exactly the per-decision overhead
    // `LearnedQueue` adds over the FIFO cases above.
    if b.enabled("fleet_event_loop_learned_1k_jobs") {
        let jobs = uniform_jobs(1_000);
        let learned = LearnedQueue::new(Mlp::new(&[N_FEATURES, 16, 1], &mut Rng::new(1)));
        let m = simulate_fleet_with(&env, &jobs, &[], &BestFit, &learned, &opts()).unwrap();
        assert_eq!(m.completed, 1_000, "learned bench jobs must all complete");
        let res = b
            .run("fleet_event_loop_learned_1k_jobs", || {
                simulate_fleet_with(&env, &jobs, &[], &BestFit, &learned, &opts()).unwrap()
            })
            .cloned();
        if let Some(r) = res {
            println!(
                "    -> {:.0} events/sec, {:.0} jobs/sec ({} events, {} jobs)",
                m.events as f64 / r.summary.mean,
                m.completed as f64 / r.summary.mean,
                m.events,
                m.completed
            );
        }
    }
}
