//! Real-runtime hot-path benchmarks over the AOT artifacts: artifact
//! execution latency, activation-cache IO, quantization, and JSON
//! plumbing. These are the numbers behind EXPERIMENTS.md §Perf (L3).
//!
//! Run: `cargo bench --bench bench_runtime` (needs `make artifacts`).

use std::sync::Arc;

use pacpp::cache::ActivationCache;
use pacpp::data::SyntheticTask;
use pacpp::quant::{dequantize, quantize, Bits};
use pacpp::runtime::{Runtime, Tensor};
use pacpp::util::bench::Bench;
use pacpp::util::rng::Rng;

fn main() {
    let mut b = Bench::new("runtime");
    let dir = std::env::var("PACPP_ARTIFACTS").unwrap_or("artifacts/small".into());
    let rt = match Runtime::load(&dir) {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            println!("skipping runtime benches ({e:#}); run `make artifacts` first");
            return;
        }
    };
    if let Err(e) = rt.executable("backbone_fwd") {
        // artifacts exist but no PJRT backend (built without `pjrt`)
        println!("skipping runtime benches: {e:#}");
        return;
    }
    let cfg = rt.manifest.config.clone();
    println!(
        "artifacts: {} (L={} d={} B={} S={})",
        dir, cfg.layers, cfg.d_model, cfg.batch, cfg.seq_len
    );

    let task = SyntheticTask::generate(cfg.batch * 2, cfg.seq_len, cfg.vocab, 0.0, 3);
    let (tokens, labels) = task.batches(cfg.batch).remove(0);

    // --- backbone forward (epoch-1 per-microbatch cost) -------------------
    let mut binputs = rt.load_params("backbone").unwrap();
    binputs.push(Tensor::I32(tokens.clone(), vec![cfg.batch, cfg.seq_len]));
    rt.executable("backbone_fwd").unwrap(); // compile outside timing
    b.run("execute/backbone_fwd", || rt.execute("backbone_fwd", &binputs).unwrap());
    let acts = rt.execute("backbone_fwd", &binputs).unwrap().remove(0);

    // --- quantized backbone forward ---------------------------------------
    if rt.manifest.artifacts.contains_key("qbackbone_fwd_int8") {
        let mut qinputs = rt.load_params("backbone_int8").unwrap();
        qinputs.push(Tensor::I32(tokens.clone(), vec![cfg.batch, cfg.seq_len]));
        rt.executable("qbackbone_fwd_int8").unwrap();
        b.run("execute/qbackbone_fwd_int8", || {
            rt.execute("qbackbone_fwd_int8", &qinputs).unwrap()
        });
    }

    // --- adapter step on cached activations (phase-2 hot path) ------------
    let mut ainputs = rt.load_params("adapter_prune").unwrap();
    ainputs.push(acts.clone());
    ainputs.push(Tensor::I32(labels.clone(), vec![cfg.batch]));
    ainputs.push(Tensor::F32(vec![0.1], vec![]));
    rt.executable("adapter_step").unwrap();
    b.run("execute/adapter_step(cached)", || rt.execute("adapter_step", &ainputs).unwrap());

    let mut ginputs = rt.load_params("adapter_prune").unwrap();
    ginputs.push(acts.clone());
    ginputs.push(Tensor::I32(labels.clone(), vec![cfg.batch]));
    rt.executable("adapter_grads").unwrap();
    b.run("execute/adapter_grads", || rt.execute("adapter_grads", &ginputs).unwrap());

    // --- activation cache IO ----------------------------------------------
    let entry_len = acts.numel();
    let dir_c = std::env::temp_dir().join("pacpp_bench_cache");
    let mut cache = ActivationCache::open(&dir_c, 8, entry_len).unwrap();
    let slab = acts.as_f32().unwrap().to_vec();
    b.run(&format!("cache/put({}KB)", entry_len * 4 / 1024), || {
        cache.put(0, &slab).unwrap()
    });
    b.run("cache/get", || cache.get(0).unwrap());
    cache.clear().unwrap();

    // --- block-wise quantization kernel ------------------------------------
    let mut rng = Rng::new(5);
    for (k, n) in [(768, 768), (1024, 4096)] {
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        b.run(&format!("quant/int8/{k}x{n}"), || quantize(&w, k, n, Bits::Int8, 64));
        let q = quantize(&w, k, n, Bits::Int8, 64);
        b.run(&format!("dequant/int8/{k}x{n}"), || dequantize(&q));
    }

    // --- manifest / JSON plumbing ------------------------------------------
    let manifest_text =
        std::fs::read_to_string(format!("{dir}/manifest.json")).unwrap();
    b.run("json/parse_manifest", || {
        pacpp::util::json::Json::parse(&manifest_text).unwrap()
    });
    b.run("params/load_adapter_set", || rt.load_params("adapter_prune").unwrap());
}
