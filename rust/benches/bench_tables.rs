//! Paper table/figure regeneration harness — one target per table AND
//! figure of the evaluation section (§VI). Usage:
//!
//! ```bash
//! cargo bench --bench bench_tables              # everything simulated
//! cargo bench --bench bench_tables -- table5    # one table
//! cargo bench --bench bench_tables -- fig16
//! PACPP_REAL=1 cargo bench --bench bench_tables -- table6   # real runs
//! ```
//!
//! The real-training targets (table6/table7/fig14) execute actual PJRT
//! training on `artifacts/small` and are gated behind `PACPP_REAL=1`
//! (they take minutes, not milliseconds) plus the `pjrt` cargo feature.
//!
//! The simulated tables resolve systems through the strategy registry
//! and evaluate their cells on worker threads (`util::par_map`), so this
//! whole suite regenerates at core-count speed.

use std::sync::Arc;

use pacpp::exp;
use pacpp::runtime::Runtime;
use pacpp::util::bench::Bench;

fn main() {
    let mut b = Bench::new("paper-tables");

    b.table("fig3", exp::print_fig3);
    b.table("table1", exp::print_table1);
    b.table("table5", exp::print_table5);
    b.table("fig12", exp::print_fig12);
    b.table("fig13", exp::print_fig13);
    b.table("fig15", exp::print_fig15);
    b.table("fig16", exp::print_fig16);
    b.table("fig17", exp::print_fig17);
    b.table("fig18", exp::print_fig18);

    // design-choice ablations (DESIGN.md §5)
    b.table("ablate_schedule", exp::ablations::print_ablate_schedule);
    b.table("ablate_bandwidth", exp::ablations::print_ablate_bandwidth);
    b.table("ablate_microbatches", exp::ablations::print_ablate_microbatches);

    let real = std::env::var("PACPP_REAL").is_ok();
    if real {
        let dir = std::env::var("PACPP_ARTIFACTS").unwrap_or("artifacts/small".into());
        let rt = Arc::new(Runtime::load(&dir).expect("run `make artifacts` first"));
        let budget = exp::accuracy::Budget::default();
        b.table("table6", || {
            exp::accuracy::print_table6(&rt, budget).unwrap();
        });
        b.table("table7", || {
            exp::accuracy::print_table7(&rt, budget).unwrap();
        });
        b.table("fig14", || {
            exp::accuracy::print_fig14(&rt, budget).unwrap();
        });
    } else if b.enabled("table6") || b.enabled("table7") || b.enabled("fig14") {
        println!(
            "\n(table6/table7/fig14 run real PJRT training; set PACPP_REAL=1 to include them)"
        );
    }
}
