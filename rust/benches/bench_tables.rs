//! Paper table/figure regeneration harness over the experiment
//! registry — one target per registered experiment. Usage:
//!
//! ```bash
//! cargo bench --bench bench_tables              # everything simulated
//! cargo bench --bench bench_tables -- table5    # one experiment
//! cargo bench --bench bench_tables -- render    # Report rendering micro-bench
//! PACPP_REAL=1 cargo bench --bench bench_tables -- table6   # real runs
//! ```
//!
//! The real-training targets (table6/table7/fig14) execute actual PJRT
//! training on `artifacts/small` and are gated behind `PACPP_REAL=1`
//! (they take minutes, not milliseconds) plus the `pjrt` cargo feature.
//!
//! The simulated experiments resolve systems through the strategy
//! registry and evaluate their cells on worker threads
//! (`util::par_map`), so this whole suite regenerates at core-count
//! speed. The `render_*` targets time text vs JSON vs CSV rendering of
//! a large sweep-shaped Report (the formats the `--out` pipeline pays
//! for).

use pacpp::exp::{Cell, ExpContext, ExperimentRegistry, Format, Report};
use pacpp::util::bench::Bench;

/// The real sweep schema (`exp::sweep_schema`) filled with `n`
/// synthetic rows, for rendering benches.
fn synthetic_sweep(n: usize) -> Report {
    let mut r = pacpp::exp::sweep_schema().meta("rows", n);
    for i in 0..n {
        let f = i as f64;
        if i % 7 == 0 {
            r.push(vec![
                Cell::Str(format!("env_{}", i % 5)),
                Cell::Str(format!("model_{}", i % 11)),
                Cell::Str(format!("strategy_{}", i % 3)),
                Cell::Str("insufficient memory".into()),
                Cell::Missing,
                Cell::Missing,
                Cell::Missing,
                Cell::Missing,
                Cell::Missing,
                Cell::Missing,
                Cell::Missing,
            ]);
        } else {
            r.push(vec![
                Cell::Str(format!("env_{}", i % 5)),
                Cell::Str(format!("model_{}", i % 11)),
                Cell::Str(format!("strategy_{}", i % 3)),
                Cell::Str("ok".into()),
                Cell::Secs(1.0 + f * 0.37),
                Cell::Secs(3.0 + f * 1.11),
                Cell::Float((3.0 + f * 1.11) / 3600.0),
                Cell::Float(1000.0 / (1.0 + f * 0.37)),
                Cell::Bytes(1_000_000 + (i as u64) * 4096),
                Cell::Int((i % 8) as i64 + 1),
                Cell::Str(format!("[{}|{}]", i % 8, 8 - i % 8)),
            ]);
        }
    }
    r
}

fn main() {
    let mut b = Bench::new("paper-tables");
    let registry = ExperimentRegistry::with_defaults();

    let dir = std::env::var("PACPP_ARTIFACTS").unwrap_or("artifacts/small".into());
    let ctx = ExpContext::with_artifacts(dir);

    // The line-up comes from the registry itself, so a newly registered
    // experiment is benched without touching this file; the ones that
    // need the AOT artifact set are gated behind PACPP_REAL.
    let real = std::env::var("PACPP_REAL").is_ok();
    let mut skipped_real: Vec<&str> = Vec::new();
    for e in registry.iter() {
        let name = e.name();
        if e.requires_artifacts() && !real {
            if b.enabled(name) {
                skipped_real.push(name);
            }
            continue;
        }
        b.table(name, || {
            match e.run(&ctx) {
                Ok(report) => print!("{}", report.to_text()),
                Err(err) => println!("{name}: {err:#}"),
            }
        });
    }
    if !skipped_real.is_empty() {
        println!(
            "\n({} run real PJRT training; set PACPP_REAL=1 to include them)",
            skipped_real.join("/")
        );
    }

    // Report rendering: text vs JSON vs CSV on a 10k-row sweep Report.
    // (Don't build the 10k-row report when a filter excludes these.)
    let render_benches = [
        "render_text_10k_rows",
        "render_json_10k_rows",
        "render_csv_10k_rows",
        "json_parse_roundtrip_10k_rows",
    ];
    if render_benches.iter().any(|n| b.enabled(n)) {
        let big = synthetic_sweep(10_000);
        b.run("render_text_10k_rows", || big.render(Format::Text));
        b.run("render_json_10k_rows", || big.render(Format::Json));
        b.run("render_csv_10k_rows", || big.render(Format::Csv));
        if b.enabled("json_parse_roundtrip_10k_rows") {
            let json = big.render(Format::Json);
            b.run("json_parse_roundtrip_10k_rows", move || {
                pacpp::util::json::Json::parse(&json).expect("parses")
            });
        }
    }
}
