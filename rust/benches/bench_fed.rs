//! Federated round-engine throughput benches. Usage:
//!
//! ```bash
//! cargo bench --bench bench_fed             # all cases
//! cargo bench --bench bench_fed -- 1000     # just the 1000-client case
//! ```
//!
//! The `fed_rounds_*_clients` cases measure the round engine
//! (candidate scan, selection, straggler decision, accounting) at
//! population sizes of 100 and 1000 — local-epoch costing is memoized
//! by client shape in the shared `StrategyOracle`, so after the first
//! quote the bench times the engine, not the planner — and report
//! derived rounds/sec next to the wall-clock summary. The `_dropout`
//! case runs the flaky trace + deadline cutoff, adding the dropout and
//! partial-aggregation paths to the measured loop. The 100k-client
//! scale case drives the SoA per-client state and the sharded quoting
//! pass — the population size the paper's edge pools imply — and the
//! `fed_async_100k_clients` case runs the same population through the
//! FedBuff-style buffered engine with Oort-style utility selection.

use pacpp::fed::{simulate_fed, AggregationMode, FedOptions, FedTraceKind};
use pacpp::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fed");

    for n in [100usize, 1000] {
        let name = format!("fed_rounds_{n}_clients");
        if !b.enabled(&name) {
            continue;
        }
        // the default 14-day horizon bounds availability-trace length
        // (toggles are materialized per client) while comfortably
        // fitting 50 rounds
        let opts = FedOptions {
            rounds: 50,
            clients: n,
            k: 16,
            trace: FedTraceKind::Churny,
            ..Default::default()
        };
        let m = simulate_fed(&opts).unwrap();
        assert!(m.rounds > 0, "bench run must complete rounds");
        let res = b.run(&name, || simulate_fed(&opts).unwrap()).cloned();
        if let Some(r) = res {
            println!(
                "    -> {:.1} rounds/sec ({} rounds, {} aggregated, {} dropped, {} stalls)",
                m.rounds as f64 / r.summary.mean,
                m.rounds,
                m.aggregated_total,
                m.dropped_total,
                m.stalls
            );
        }
    }

    // Scale case: 100k clients through the SoA round engine. Trace
    // generation and the per-client quoting pass shard across cores
    // (`shards: 0` = auto) — the property tests pin the shard count as
    // metric-invariant, so this measures the same computation the
    // small cases do. Fewer rounds keep the wall-clock per iteration
    // in bench range.
    if b.enabled("fed_rounds_100k_clients") {
        let opts = FedOptions {
            rounds: 10,
            clients: 100_000,
            k: 256,
            trace: FedTraceKind::Churny,
            ..Default::default()
        };
        let m = simulate_fed(&opts).unwrap();
        assert!(m.rounds > 0, "scale bench run must complete rounds");
        let res = b
            .run("fed_rounds_100k_clients", || simulate_fed(&opts).unwrap())
            .cloned();
        if let Some(r) = res {
            println!(
                "    -> {:.1} rounds/sec ({} rounds, {} aggregated, {} dropped, {} stalls)",
                m.rounds as f64 / r.summary.mean,
                m.rounds,
                m.aggregated_total,
                m.dropped_total,
                m.stalls
            );
        }
    }

    // Async scale case: 100k clients through the FedBuff-style
    // buffered engine with utility selection — per-dispatch candidate
    // scans and the arrival heap are the measured loop here, the
    // async analogue of the sync 100k case above.
    if b.enabled("fed_async_100k_clients") {
        let opts = FedOptions {
            rounds: 10,
            clients: 100_000,
            k: 128,
            agg_mode: AggregationMode::Async,
            buffer_k: 32,
            select: "utility".into(),
            trace: FedTraceKind::Churny,
            ..Default::default()
        };
        let m = simulate_fed(&opts).unwrap();
        assert!(m.rounds > 0, "async scale bench run must complete rounds");
        let res = b
            .run("fed_async_100k_clients", || simulate_fed(&opts).unwrap())
            .cloned();
        if let Some(r) = res {
            println!(
                "    -> {:.1} rounds/sec ({} rounds, {} aggregated, {} dropped, stale p50 {:?})",
                m.rounds as f64 / r.summary.mean,
                m.rounds,
                m.aggregated_total,
                m.dropped_total,
                m.staleness_p50
            );
        }
    }

    if b.enabled("fed_rounds_dropout_1000_clients") {
        let opts = FedOptions {
            rounds: 50,
            clients: 1000,
            k: 16,
            select: "power-of-d".into(),
            straggler: "deadline".into(),
            trace: FedTraceKind::Flaky,
            ..Default::default()
        };
        let m = simulate_fed(&opts).unwrap();
        let res = b
            .run("fed_rounds_dropout_1000_clients", || simulate_fed(&opts).unwrap())
            .cloned();
        if let Some(r) = res {
            println!(
                "    -> {:.1} rounds/sec ({} rounds, {} aggregated, {} dropped)",
                m.rounds as f64 / r.summary.mean,
                m.rounds,
                m.aggregated_total,
                m.dropped_total
            );
        }
    }
}
