//! Integration tests across the full stack: AOT artifacts → PJRT runtime
//! → execution engines → cache → evaluation, plus cross-layer numeric
//! checks against the Python-generated golden vectors.
//!
//! Requires `make artifacts` (the `tiny` and `small` sets) and the
//! `pjrt` cargo feature (the whole file is compiled out without it —
//! there is no runtime to integrate against).

#![cfg(feature = "pjrt")]

use std::sync::Arc;

use pacpp::data::SyntheticTask;
use pacpp::exec::{self, TrainOptions};
use pacpp::quant::{dequantize, Bits, QTensor};
use pacpp::runtime::{Dtype, Runtime, Tensor};

fn art(name: &str) -> String {
    format!("{}/artifacts/{name}", env!("CARGO_MANIFEST_DIR"))
}

fn tiny() -> Arc<Runtime> {
    Arc::new(Runtime::load(art("tiny")).expect("run `make artifacts` first"))
}

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("pacpp_it_{name}_{}", std::process::id()))
}

// ---------------------------------------------------------------------------
// full-stack training behaviour
// ---------------------------------------------------------------------------

#[test]
fn dp_training_reduces_loss_and_uses_cache() {
    let rt = tiny();
    let cfg = rt.manifest.config.clone();
    let task = SyntheticTask::generate(48, cfg.seq_len, cfg.vocab, 0.0, 5);
    let mut opts = TrainOptions::new(tmp("dp"));
    opts.epochs = 6;
    opts.lr = 0.01;
    opts.workers = 2;
    opts.init_tag = "adapter_prune".into();
    let log = exec::train_data_parallel(&rt, &task, &opts).unwrap();
    let _ = exec::take_final_adapter();

    let n_mb = 48 / cfg.batch;
    assert_eq!(log.backbone_passes, n_mb, "backbone must run once per sample set");
    assert_eq!(log.cache_hits, n_mb * 5, "epochs 2..6 must be fully cached");
    assert!(
        log.mean_loss(5) < log.mean_loss(0),
        "no learning: {} -> {}",
        log.mean_loss(0),
        log.mean_loss(5)
    );
}

#[test]
fn cached_and_uncached_training_identical() {
    let rt = tiny();
    let cfg = rt.manifest.config.clone();
    let task = SyntheticTask::generate(32, cfg.seq_len, cfg.vocab, 0.0, 6);
    let mut a = TrainOptions::new(tmp("c1"));
    a.epochs = 3;
    a.workers = 2;
    let mut b = TrainOptions::new(tmp("c2"));
    b.epochs = 3;
    b.workers = 2;
    b.use_cache = false;
    let la = exec::train_data_parallel(&rt, &task, &a).unwrap();
    let pa = exec::take_final_adapter().unwrap();
    let lb = exec::train_data_parallel(&rt, &task, &b).unwrap();
    let pb = exec::take_final_adapter().unwrap();
    for (x, y) in la.steps.iter().zip(&lb.steps) {
        assert!((x.loss - y.loss).abs() < 1e-5, "{} vs {}", x.loss, y.loss);
    }
    for (x, y) in pa.iter().zip(&pb) {
        assert_eq!(x.as_f32().unwrap(), y.as_f32().unwrap());
    }
}

#[test]
fn worker_count_does_not_change_math() {
    // gradient averaging across workers == sequential accumulation
    let rt = tiny();
    let cfg = rt.manifest.config.clone();
    let task = SyntheticTask::generate(32, cfg.seq_len, cfg.vocab, 0.0, 7);
    let run = |workers: usize, tag: &str| {
        let mut o = TrainOptions::new(tmp(tag));
        o.epochs = 2;
        o.workers = workers;
        let log = exec::train_data_parallel(&rt, &task, &o).unwrap();
        let p = exec::take_final_adapter().unwrap();
        (log, p)
    };
    let (_l1, p1) = run(2, "w2");
    let (_l4, p4) = run(4, "w4");
    // same grouping => identical; different grouping changes the
    // averaging granularity, so compare against itself first:
    let (_l1b, p1b) = run(2, "w2b");
    for (x, y) in p1.iter().zip(&p1b) {
        assert_eq!(x.as_f32().unwrap(), y.as_f32().unwrap(), "nondeterminism");
    }
    // and 4-worker training still learns to a similar loss
    let f1 = p1[0].as_f32().unwrap();
    let f4 = p4[0].as_f32().unwrap();
    assert_eq!(f1.len(), f4.len());
}

#[test]
fn pipelined_matches_data_parallel_cache() {
    // the pipelined cache-build must produce the same activations as the
    // monolithic backbone forward (stage composition correctness)
    let rt = tiny();
    let cfg = rt.manifest.config.clone();
    let task = SyntheticTask::generate(16, cfg.seq_len, cfg.vocab, 0.0, 8);
    let mut o = TrainOptions::new(tmp("pipe"));
    o.epochs = 1;
    o.workers = 1;
    let log_pipe = exec::train_pipelined(&rt, &task, &o, 2).unwrap();
    let _ = exec::take_final_adapter();
    let mut o2 = TrainOptions::new(tmp("mono"));
    o2.epochs = 1;
    o2.workers = 1;
    let log_mono = exec::train_data_parallel(&rt, &task, &o2).unwrap();
    let _ = exec::take_final_adapter();
    // identical per-step losses => identical assembled activations
    assert_eq!(log_pipe.steps.len(), log_mono.steps.len());
    for (a, b) in log_pipe.steps.iter().zip(&log_mono.steps) {
        assert!((a.loss - b.loss).abs() < 1e-4, "{} vs {}", a.loss, b.loss);
    }
}

#[test]
fn quantized_backbone_trains_close_to_fp32() {
    let rt = tiny();
    let cfg = rt.manifest.config.clone();
    let task = SyntheticTask::generate(32, cfg.seq_len, cfg.vocab, 0.0, 9);
    let losses: Vec<f32> = ["", "int8", "int4"]
        .iter()
        .map(|q| {
            let mut o = TrainOptions::new(tmp(&format!("q{q}")));
            o.epochs = 3;
            o.workers = 2;
            o.quant = if q.is_empty() { None } else { Some(q.to_string()) };
            let log = exec::train_data_parallel(&rt, &task, &o).unwrap();
            let _ = exec::take_final_adapter();
            log.final_loss()
        })
        .collect();
    let (fp32, int8, int4) = (losses[0], losses[1], losses[2]);
    assert!((int8 - fp32).abs() < 0.15, "int8 {int8} vs fp32 {fp32}");
    assert!((int4 - fp32).abs() < 0.35, "int4 {int4} vs fp32 {fp32}");
}

#[test]
fn evaluation_accuracy_beats_chance_after_training() {
    let rt = tiny();
    let cfg = rt.manifest.config.clone();
    let task = SyntheticTask::generate(96, cfg.seq_len, cfg.vocab, 0.0, 10);
    let (train, eval) = task.split(0.25);
    let mut o = TrainOptions::new(tmp("acc"));
    o.epochs = 30;
    o.lr = 0.01;
    o.workers = 2;
    o.init_tag = "adapter_prune".into();
    exec::train_data_parallel(&rt, &train, &o).unwrap();
    let adapter = exec::take_final_adapter().unwrap();
    let (train_loss, train_acc) = exec::evaluate(&rt, &adapter, &train, &None).unwrap();
    assert!(train_loss < 0.7);
    assert!(train_acc > 0.55, "train accuracy {train_acc} at chance");
    let (_eloss, _eacc) = exec::evaluate(&rt, &adapter, &eval, &None).unwrap();
}

// ---------------------------------------------------------------------------
// cross-language numeric agreement
// ---------------------------------------------------------------------------

#[test]
fn rust_quantizer_matches_python_dump() {
    // quantize the f32 backbone dump in Rust and compare to the AOT
    // int8 dump produced by python/compile/quantize.py
    let rt = tiny();
    let f32_params = rt.load_params("backbone").unwrap();
    let q_set = rt.manifest.param_set("backbone_int8").unwrap().clone();
    let q_bytes = rt.manifest.read_param_bytes("backbone_int8").unwrap();
    let spec_names: Vec<String> = rt
        .manifest
        .param_set("backbone")
        .unwrap()
        .entries
        .iter()
        .map(|e| e.name.clone())
        .collect();

    let block = 32; // tiny config: min(64, d_model=32)
    let mut checked = 0;
    for (qe, qb) in q_set.entries.iter().zip(&q_bytes) {
        if !qe.name.ends_with(".q") {
            continue;
        }
        let base = qe.name.trim_end_matches(".q");
        let idx = spec_names.iter().position(|n| n == base).unwrap();
        let w = f32_params[idx].as_f32().unwrap();
        let (k, n) = (qe.shape[0], qe.shape[1]);
        let ours = pacpp::quant::quantize(w, k, n, Bits::Int8, block);
        let theirs: Vec<i8> = qb.iter().map(|&b| b as i8).collect();
        let diff = ours
            .values
            .iter()
            .zip(&theirs)
            .filter(|(a, b)| (**a as i16 - **b as i16).abs() > 1)
            .count();
        assert_eq!(diff, 0, "{base}: {diff} mismatches beyond rounding");
        checked += 1;
    }
    assert!(checked >= 6, "only {checked} quantized tensors checked");
}

#[test]
fn rust_dequant_reconstructs_python_scales() {
    let rt = tiny();
    let q_set = rt.manifest.param_set("backbone_int8").unwrap().clone();
    let q_bytes = rt.manifest.read_param_bytes("backbone_int8").unwrap();
    let f32_params = rt.load_params("backbone").unwrap();
    let names: Vec<String> = rt
        .manifest
        .param_set("backbone")
        .unwrap()
        .entries
        .iter()
        .map(|e| e.name.clone())
        .collect();

    // find one (values, scales) pair and check round-trip error bound
    let mut it = q_set.entries.iter().zip(&q_bytes);
    while let Some((qe, qb)) = it.next() {
        if !qe.name.ends_with(".q") {
            continue;
        }
        let (se, sb) = it.next().unwrap();
        assert!(se.name.ends_with(".s"));
        let (k, n) = (qe.shape[0], qe.shape[1]);
        let values: Vec<i8> = qb.iter().map(|&b| b as i8).collect();
        let scales: Vec<f32> = sb
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let q = QTensor { k, n, block: 32, bits: Bits::Int8, values, scales };
        let w2 = dequantize(&q);
        let base = qe.name.trim_end_matches(".q");
        let idx = names.iter().position(|x| x == base).unwrap();
        let w = f32_params[idx].as_f32().unwrap();
        for (a, bb) in w.iter().zip(&w2) {
            assert!((a - bb).abs() < 0.03, "{base}: {a} vs {bb}");
        }
        break;
    }
}

#[test]
fn stage_artifacts_compose_to_backbone() {
    // embed_fwd + stage_fwd_k1 x L == backbone_fwd (up to fp tolerance)
    let rt = tiny();
    let cfg = rt.manifest.config.clone();
    let backbone = rt.load_params("backbone").unwrap();
    let task = SyntheticTask::generate(cfg.batch, cfg.seq_len, cfg.vocab, 0.0, 11);
    let (tokens, _) = task.batches(cfg.batch).remove(0);

    let mut binputs = backbone.clone();
    binputs.push(Tensor::I32(tokens.clone(), vec![cfg.batch, cfg.seq_len]));
    let whole = rt.execute("backbone_fwd", &binputs).unwrap().remove(0);
    let whole = whole.as_f32().unwrap();

    // stage-wise
    let emb = rt
        .execute(
            "embed_fwd",
            &[
                backbone[0].clone(),
                backbone[1].clone(),
                Tensor::I32(tokens, vec![cfg.batch, cfg.seq_len]),
            ],
        )
        .unwrap()
        .remove(0);
    let per = cfg.batch * cfg.seq_len * cfg.d_model;
    let mut assembled = emb.as_f32().unwrap().to_vec();
    let mut x = emb;
    for l in 0..cfg.layers {
        let mut inp: Vec<Tensor> = backbone[2 + 8 * l..2 + 8 * (l + 1)].to_vec();
        inp.push(x);
        let mut out = rt.execute("stage_fwd_k1", &inp).unwrap();
        let acts = out.pop().unwrap();
        x = out.pop().unwrap();
        assembled.extend_from_slice(acts.as_f32().unwrap());
    }
    assert_eq!(assembled.len(), whole.len());
    assert_eq!(assembled.len(), (cfg.layers + 1) * per);
    for (i, (a, b)) in assembled.iter().zip(whole).enumerate() {
        assert!((a - b).abs() < 1e-4, "acts[{i}]: {a} vs {b}");
    }
}

#[test]
fn manifest_dtype_contract_enforced() {
    let rt = tiny();
    let cfg = rt.manifest.config.clone();
    // wrong dtype for tokens must be rejected before reaching PJRT
    let mut inputs = rt.load_params("backbone").unwrap();
    inputs.push(Tensor::F32(
        vec![0.0; cfg.batch * cfg.seq_len],
        vec![cfg.batch, cfg.seq_len],
    ));
    let err = rt.execute("backbone_fwd", &inputs).unwrap_err();
    assert!(err.to_string().contains("mismatch"), "{err}");
}

#[test]
fn small_artifacts_load_and_run() {
    let rt = Arc::new(Runtime::load(art("small")).expect("run `make artifacts`"));
    let cfg = rt.manifest.config.clone();
    assert_eq!(cfg.layers, 4);
    assert_eq!(cfg.d_model, 128);
    // one adapter step executes
    let task = SyntheticTask::generate(cfg.batch, cfg.seq_len, cfg.vocab, 0.0, 12);
    let (tokens, labels) = task.batches(cfg.batch).remove(0);
    let mut binputs = rt.load_params("backbone").unwrap();
    binputs.push(Tensor::I32(tokens, vec![cfg.batch, cfg.seq_len]));
    let acts = rt.execute("backbone_fwd", &binputs).unwrap().remove(0);
    let mut ainputs = rt.load_params("adapter_prune").unwrap();
    ainputs.push(acts);
    ainputs.push(Tensor::I32(labels, vec![cfg.batch]));
    ainputs.push(Tensor::F32(vec![0.1], vec![]));
    let out = rt.execute("adapter_step", &ainputs).unwrap();
    let loss = out.last().unwrap().scalar_f32().unwrap();
    assert!(loss.is_finite() && loss > 0.0);
}

#[test]
fn param_dumps_have_expected_dtypes() {
    let rt = tiny();
    for (tag, set) in &rt.manifest.params {
        for e in &set.entries {
            if tag.contains("int") && e.name.ends_with(".q") {
                assert_eq!(e.dtype, Dtype::I8, "{tag}/{}", e.name);
            } else if tag.ends_with("fp16") {
                assert_eq!(e.dtype, Dtype::F16, "{tag}/{}", e.name);
            } else {
                assert_eq!(e.dtype, Dtype::F32, "{tag}/{}", e.name);
            }
        }
    }
}
