//! Conformance suite for the strategy layer: every strategy registered
//! in `StrategyRegistry::with_defaults()` is exercised over the paper's
//! default environment presets, and every `Plan` it returns must be
//! feasible — stages cover all blocks contiguously, each stage's sample
//! dispatch sums to the micro-batch size, and peak memory fits every
//! assigned device's budget. Running out of memory is a legal answer
//! (Table V's "OOM" cells); returning an infeasible plan is not.
//!
//! New strategies added to the default registry are picked up here
//! automatically — no per-strategy test code needed.

use pacpp::cluster::Env;
use pacpp::model::graph::LayerGraph;
use pacpp::model::{Method, ModelSpec, Precision};
use pacpp::profiler::Profile;
use pacpp::strategy::{ParallelismStrategy, StrategyRegistry, TrainJob};

fn profile(spec: ModelSpec, method: Method) -> Profile {
    Profile::new(LayerGraph::new(spec), method, Precision::FP32, 128)
}

/// The paper's evaluation presets: homogeneous Env.A, heterogeneous
/// Env.B, and the 8-device scalability cluster (§VI-A, §VI-G).
fn preset_envs() -> Vec<Env> {
    vec![Env::env_a(), Env::env_b(), Env::nanos(8)]
}

#[test]
fn every_strategy_yields_feasible_plans_on_presets() {
    let reg = StrategyRegistry::with_defaults();
    assert!(reg.len() >= 7, "default line-up incomplete: {:?}", reg.names());
    let job = TrainJob::new(256, 1, 128, 16);

    for (spec, method, min_feasible) in [
        (ModelSpec::t5_base(), Method::pa(false), 4),
        (ModelSpec::t5_base(), Method::adapters_default(), 4),
        // T5-Large legitimately OOMs the replicated (and sometimes the
        // even-split) systems on 4GB Nanos; the hybrid planners must fit
        (ModelSpec::t5_large(), Method::pa(false), 2),
    ] {
        let prof = profile(spec, method);
        for env in preset_envs() {
            let mut feasible = 0usize;
            for s in reg.iter() {
                let opts = s.options(&env, &job);
                let plan = match s.plan(&prof, &env, &opts) {
                    Ok(p) => p,
                    // OOM (or an empty worker set) is a legal outcome
                    Err(_) => continue,
                };
                feasible += 1;
                plan.validate(prof.graph.len(), env.n()).unwrap_or_else(|e| {
                    panic!("{} on {}: invalid plan: {e}", s.name(), env.name)
                });
                assert!(plan.microbatch_size > 0, "{} on {}", s.name(), env.name);
                assert!(plan.microbatches > 0, "{} on {}", s.name(), env.name);
                for (i, st) in plan.stages.iter().enumerate() {
                    assert_eq!(
                        st.dispatch.iter().sum::<usize>(),
                        plan.microbatch_size,
                        "{} on {}: stage {i} dispatch does not cover the micro-batch",
                        s.name(),
                        env.name
                    );
                    for d in &st.devices {
                        assert!(
                            st.peak_mem <= d.mem_budget(),
                            "{} on {}: stage {i} peak {} exceeds {} budget {}",
                            s.name(),
                            env.name,
                            st.peak_mem,
                            d.kind.name(),
                            d.mem_budget()
                        );
                    }
                }
            }
            // the pipelined strategies must always find a placement for
            // these model/method combinations (Table V has no all-OOM row)
            assert!(
                feasible >= min_feasible,
                "only {feasible} strategies feasible for {} on {}",
                prof.graph.spec.name,
                env.name
            );
        }
    }
}

#[test]
fn every_strategy_runs_end_to_end_on_env_b() {
    let reg = StrategyRegistry::with_defaults();
    let prof = profile(ModelSpec::t5_base(), Method::pa(true));
    let job = TrainJob::new(512, 3, 128, 16);
    let env = Env::env_b();
    let mut ran = 0usize;
    for s in reg.iter() {
        let r = match s.run(&prof, &env, job) {
            Ok(r) => r,
            Err(_) => continue,
        };
        ran += 1;
        assert!(
            r.epoch1.is_finite() && r.epoch1 > 0.0,
            "{}: epoch1 {}",
            s.name(),
            r.epoch1
        );
        assert!(r.total.is_finite() && r.total > 0.0, "{}: total {}", s.name(), r.total);
        assert_eq!(r.epochs, job.epochs, "{}", s.name());
        let expect = r.epoch1 + r.redistribution + r.epoch_cached * (job.epochs - 1) as f64;
        assert!(
            (r.total - expect).abs() <= 1e-9 * expect.max(1.0),
            "{}: total {} != breakdown {}",
            s.name(),
            r.total,
            expect
        );
        r.plan.validate(prof.graph.len(), env.n()).unwrap_or_else(|e| {
            panic!("{}: run-report plan invalid: {e}", s.name())
        });
    }
    assert!(ran >= 5, "only {ran} strategies produced a run report");
}

#[test]
fn options_cover_the_job_minibatch() {
    // every strategy's planner options must cover the mini-batch: the
    // micro-batch size times the pipelining depth processes at least
    // job.minibatch samples per mini-batch
    let reg = StrategyRegistry::with_defaults();
    let env = Env::env_a();
    for minibatch in [4usize, 16, 64] {
        let job = TrainJob::new(100, 1, 128, minibatch);
        for s in reg.iter() {
            let opts = s.options(&env, &job);
            assert!(opts.microbatch > 0, "{}", s.name());
            assert!(
                opts.microbatch * opts.n_microbatches >= minibatch,
                "{}: B={} M={} does not cover minibatch {}",
                s.name(),
                opts.microbatch,
                opts.n_microbatches,
                minibatch
            );
        }
    }
}
