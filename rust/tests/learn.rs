//! The learned-scheduler acceptance test: a DQN trained *in* the fleet
//! simulator must strictly beat the best hand-written discipline
//! (FIFO, EASY-backfill, EDF) on deadline-met count — equivalently,
//! deadline-miss rate — over held-out scenario seeds disjoint from
//! every training seed.
//!
//! Construction follows the probe pattern (see `tests/fleet.rs`):
//! service times are measured by probe runs, then deadlines are built
//! relative to them with wide margins, and the preconditions are
//! asserted so a cost-model change fails loudly at the probe, not
//! mysteriously at the claim.
//!
//! The engineered scenario (single-device pool, so every decision is a
//! pure ordering choice):
//!
//! * a blocker `B` (short, huge deadline slack) arrives at t=0 and
//!   holds the device while everything else arrives;
//! * three **hopeless** jobs `H` (long, `deadline_mult ~ 0.5`) arrive
//!   next. Their deadline is half their own ideal service time, so
//!   they miss under *every* policy — but their *absolute* deadlines
//!   are the earliest in the queue, so EDF serves them first;
//! * three **tight-but-feasible** jobs `T` (short) arrive last, with
//!   deadlines ~1.5 long-job service times out: met if at most one `H`
//!   runs before them, missed once two or more do.
//!
//! FIFO and EASY-backfill (single device: nothing can ever backfill)
//! run the queue in arrival order — all three `H` first — and EDF
//! picks the earliest absolute deadlines, which are also the `H`s. All
//! three baselines therefore meet exactly one deadline per scenario
//! (the blocker's). The learned policy sees laxity/slack features that
//! separate `T` from `H` linearly and earns +1 only for deadline-met
//! dispatches, so training drives it to serve the feasible jobs first:
//! any single met `T` anywhere in the held-out set already beats every
//! baseline strictly.

use pacpp::cluster::Env;
use pacpp::fleet::{simulate_fleet, simulate_fleet_with, BestFit, FleetOptions, Job};
use pacpp::learn::{held_out_seed, train_seed, DqnAgent, DqnConfig, LearnedQueue, TrainerQueue};
use pacpp::model::ModelSpec;
use pacpp::util::rng::Rng;

/// Short job shape: the blocker and the tight-but-feasible jobs.
fn short_shape(id: usize, arrival: f64) -> Job {
    Job::new(id, arrival, ModelSpec::t5_base(), 512, 2)
}

/// Long job shape: the hopeless jobs.
fn long_shape(id: usize, arrival: f64) -> Job {
    Job::new(id, arrival, ModelSpec::t5_base(), 4096, 4)
}

/// One seeded scenario instance: blocker + 3 hopeless + 3 tight, with
/// jittered arrivals and deadline multipliers. The jitter windows keep
/// ids arrival-sorted and every margin below intact, so each seed is a
/// distinct workload with the same engineered structure.
fn scenario(seed: u64, t_short: f64, t_long: f64) -> Vec<Job> {
    let mut rng = Rng::new(seed ^ 0xACC3_97);
    let mut jobs = vec![short_shape(0, 0.0).with_deadline_mult(100.0)];
    for i in 0..3 {
        let arrival = 5.0 + 5.0 * i as f64 + 2.0 * rng.f64();
        let mult = 0.5 * (0.9 + 0.2 * rng.f64());
        jobs.push(long_shape(1 + i, arrival).with_deadline_mult(mult));
    }
    for i in 0..3 {
        let arrival = 20.0 + 5.0 * i as f64 + 2.0 * rng.f64();
        // deadline = arrival + ~1.5 x t_long: survives one hopeless job
        // ahead of it, never two (preconditions asserted in the test)
        let mult = 1.5 * t_long / t_short * (0.95 + 0.1 * rng.f64());
        jobs.push(short_shape(4 + i, arrival).with_deadline_mult(mult));
    }
    jobs
}

#[test]
fn trained_policy_beats_every_handwritten_baseline_on_held_out_seeds() {
    let env = Env::nanos(1);
    let probe = |job: Job| -> f64 {
        let jobs = vec![Job { id: 0, arrival: 0.0, ..job }];
        let m = simulate_fleet(&env, &jobs, &[], &BestFit, &FleetOptions::default()).unwrap();
        assert_eq!(m.completed, 1, "probe must complete");
        m.makespan
    };
    // the pool IS one device, so the probe makespans are the oracle's
    // full-pool references the deadline multipliers anchor on
    let t_short = probe(short_shape(0, 0.0));
    let t_long = probe(long_shape(0, 0.0));

    // preconditions, with the worst-case jitter values:
    // 1. every arrival (< 34 s) lands while the blocker still runs
    assert!(t_short > 40.0, "blocker must outlive all arrivals: {t_short}");
    // 2. tights met behind one hopeless job: B + H + 3 T fits inside
    //    the smallest tight deadline (1.425 x t_long + 20)
    assert!(
        4.0 * t_short + t_long < 20.0 + 1.425 * t_long,
        "tights must survive one hopeless job ahead: t_short {t_short}, t_long {t_long}"
    );
    // 3. tights missed behind two: B + 2 H already overshoots the
    //    largest tight deadline (1.575 x t_long + 34)
    assert!(
        t_short + 2.0 * t_long > 34.0 + 1.575 * t_long,
        "two hopeless jobs must sink every tight deadline: {t_short}, {t_long}"
    );
    // 4. hopeless jobs are hopeless: started even at the earliest
    //    possible instant (the blocker's finish), they overshoot their
    //    own largest deadline (0.55 x t_long + 18)
    assert!(
        t_short + t_long > 18.0 + 0.55 * t_long,
        "hopeless jobs must miss under every policy: {t_short}, {t_long}"
    );
    // generous horizon: every policy finishes all 7 jobs
    let horizon = 2.0 * (3.0 * t_long + 4.0 * t_short);
    let opts = FleetOptions { horizon, ..Default::default() };

    // train on even seeds only (held_out_seed is always odd — the
    // spaces are provably disjoint, property-tested in the learn crate)
    let dqn = DqnConfig {
        min_replay: 24,
        batch: 16,
        batches_per_episode: 8,
        ..DqnConfig::default()
    };
    let trainer = TrainerQueue::new(DqnAgent::new(dqn, 2024));
    for e in 0..60 {
        let jobs = scenario(train_seed(2024, e), t_short, t_long);
        let m = simulate_fleet_with(&env, &jobs, &[], &BestFit, &trainer, &opts).unwrap();
        trainer.finish_episode(&m);
    }
    let learned = LearnedQueue::new(trainer.into_agent().into_net());

    let mut learned_met = 0usize;
    let mut baseline_met = [0usize; 3];
    let baselines = ["fifo", "backfill", "edf"];
    for i in 0..3 {
        let jobs = scenario(held_out_seed(i), t_short, t_long);
        let lm = simulate_fleet_with(&env, &jobs, &[], &BestFit, &learned, &opts).unwrap();
        assert_eq!(lm.completed, 7, "learned run must finish everything: {lm:?}");
        learned_met += lm.deadline_met;
        for (b, queue) in baselines.iter().enumerate() {
            let bopts = FleetOptions { queue: (*queue).into(), ..opts.clone() };
            let m = simulate_fleet(&env, &jobs, &[], &BestFit, &bopts).unwrap();
            assert_eq!(m.completed, 7, "{queue} must finish everything: {m:?}");
            // the engineered guarantee: arrival order and deadline
            // order both put the hopeless jobs first, so every
            // baseline meets exactly the blocker's deadline
            assert_eq!(
                m.deadline_met, 1,
                "{queue} on held-out seed {i} must meet only the blocker: {m:?}"
            );
            baseline_met[b] += m.deadline_met;
        }
    }

    let best_baseline = baseline_met.iter().copied().max().unwrap();
    assert!(
        learned_met > best_baseline,
        "learned policy must strictly beat the best baseline on deadline-met count \
         (= strictly lower miss rate): learned {learned_met} vs baselines \
         {baseline_met:?} over 3 held-out seeds"
    );
}
