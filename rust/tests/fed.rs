//! Federated-simulator integration tests: the availability-aware
//! acceptance comparison (strictly more rounds than uniform-random on
//! the same churny population within a fixed horizon), the async
//! buffered-aggregation acceptance (strictly more logical rounds than
//! wait-all in the same virtual time, with staleness reported),
//! same-options bit-identical determinism across every selection ×
//! straggler combination in both aggregation modes, straggler-policy
//! separations, and end-to-end coverage of the `fed` experiments
//! through the registry.
//!
//! The engineered scenarios follow the fleet tests' probe pattern:
//! round times are *measured* by probe runs, then horizons and margins
//! are constructed relative to them — no tuned constants, and the
//! preconditions are asserted so a cost-model change fails loudly at
//! the probe, not mysteriously at the claim.

use pacpp::cluster::DeviceKind;
use pacpp::exp::{Cell, ExpContext, ExperimentRegistry, Format, Report};
use pacpp::fed::{
    simulate_fed, simulate_fed_with, AggregationMode, ClientTrace, FedClient, FedOptions,
    FedTraceKind, SelectionRegistry, StragglerRegistry,
};
use pacpp::util::json::Json;
use pacpp::util::prop::{check, forall};

/// A population for the engineered dropout scenarios: client 0 is
/// always up; clients `1..n` are identical hardware but "flaky" —
/// available almost always, yet their up-windows (`up` seconds,
/// separated by `down`-second gaps) are far shorter than a round, so a
/// flaky client selected into a round is *guaranteed* to drop out.
fn flaky_population(
    n: usize,
    horizon: f64,
    up: f64,
    down: f64,
) -> (Vec<FedClient>, Vec<ClientTrace>) {
    let clients: Vec<FedClient> =
        (0..n).map(|i| FedClient::new(i, DeviceKind::NanoH, 1024, 2)).collect();
    let mut traces = vec![ClientTrace::always_up()];
    for _ in 1..n {
        let mut toggles = Vec::new();
        let mut t = 0.0;
        loop {
            t += up;
            if t >= horizon {
                break;
            }
            toggles.push(t); // up window closes
            t += down;
            if t >= horizon {
                break;
            }
            toggles.push(t); // back up
        }
        traces.push(ClientTrace::new(true, toggles, horizon));
    }
    (clients, traces)
}

/// The ISSUE-5 acceptance run: availability-aware selection completes
/// **strictly more rounds within a fixed horizon** than uniform-random
/// on the same churny population.
///
/// Construction (probed, not tuned): K=1 over one always-up client and
/// 15 flaky ones whose 60 s up-windows are far shorter than a round
/// (precondition asserted from the probe), so any flaky selection
/// drops out and stalls its synchronous round at the server's 3×
/// give-up timeout — a 3×-cost round. Availability-aware always picks
/// the always-up client (its window outlasts any estimate), completing
/// `R` rounds in exactly `R` round-times; the horizon is set to
/// `R + 0.4` round-times, so uniform-random — which with seed 42
/// inevitably samples flaky clients — cannot fit `R` rounds unless
/// every single pick was the one stable client out of 16.
#[test]
fn availability_aware_completes_strictly_more_rounds_than_uniform() {
    const ROUNDS: usize = 12;
    let horizon_gen = 80.0 * 3600.0; // trace-generation span, re-checked below
    let (clients, traces) = flaky_population(16, horizon_gen, 60.0, 0.5);

    let base = FedOptions {
        rounds: ROUNDS,
        clients: 16,
        k: 1,
        straggler: "wait-all".into(),
        jitter: 0.0,
        ..Default::default()
    };

    // probe: availability-aware rounds are all identical (jitter off,
    // same client every round), so the probe measures one round time
    let avail_opts = FedOptions { select: "availability".into(), ..base.clone() };
    let probe = simulate_fed_with(&clients, &traces, &avail_opts).unwrap();
    assert_eq!(probe.rounds, ROUNDS, "probe must complete: {probe:?}");
    assert_eq!(probe.dropped_total, 0, "the always-up client never drops: {probe:?}");
    let round_time = probe.makespan / ROUNDS as f64;
    // preconditions that make the margins provable, asserted not assumed
    assert!(
        round_time > 2.0 * 60.0,
        "a round ({round_time} s) must dwarf the 60 s flaky up-window"
    );
    assert!(
        probe.makespan * 1.1 < horizon_gen,
        "traces must cover the acceptance horizon: {} vs {horizon_gen}",
        probe.makespan
    );

    // fixed horizon: R rounds plus 0.4 of one more. A single flaky pick
    // costs 3 round-times (dropout detection), so uniform fits R rounds
    // only by picking the one stable client R times in a row.
    let horizon = probe.makespan + 0.4 * round_time;
    let avail = simulate_fed_with(
        &clients,
        &traces,
        &FedOptions { horizon, ..avail_opts.clone() },
    )
    .unwrap();
    let uniform = simulate_fed_with(
        &clients,
        &traces,
        &FedOptions { select: "uniform".into(), horizon, ..base.clone() },
    )
    .unwrap();

    assert_eq!(avail.rounds, ROUNDS, "{avail:?}");
    assert!(
        uniform.rounds < avail.rounds,
        "availability-aware must complete strictly more rounds: \
         uniform {} vs availability-aware {}",
        uniform.rounds,
        avail.rounds
    );
    assert!(uniform.dropped_total > 0, "uniform must have hit dropouts: {uniform:?}");
    assert_eq!(avail.dropped_total, 0, "{avail:?}");
    // the convergence proxy tells the same story
    assert!(avail.effective_rounds > uniform.effective_rounds);
}

/// Straggler-policy separation on the same dropout-heavy population:
/// deadline cutoff caps what a dropout can cost (its rounds never
/// stall to the 3× give-up timeout), so its p99 round time is strictly
/// below synchronous wait-all's.
#[test]
fn deadline_cutoff_caps_dropout_stalls() {
    let horizon_gen = 80.0 * 3600.0;
    let (clients, traces) = flaky_population(8, horizon_gen, 60.0, 0.5);
    // k=4 of 8 with availability-aware selection: the stable client is
    // in every cohort (so a deadline cohort always has a finisher) and
    // at least 3 flaky picks ride along, so every wait-all round stalls
    // at 3x while every deadline round is cut at 2x the median
    // estimate. (Uniform selection would occasionally draw all-flaky
    // cohorts, which the degenerate-cohort fix now makes wait out the
    // dropouts instead of aggregating nothing early — identical to
    // wait-all, which would erase the separation this test asserts.)
    let base = FedOptions {
        rounds: 6,
        clients: 8,
        k: 4,
        select: "availability".into(),
        jitter: 0.0,
        deadline_mult: 2.0,
        ..Default::default()
    };
    let wait = simulate_fed_with(
        &clients,
        &traces,
        &FedOptions { straggler: "wait-all".into(), ..base.clone() },
    )
    .unwrap();
    let cut = simulate_fed_with(
        &clients,
        &traces,
        &FedOptions { straggler: "deadline".into(), ..base.clone() },
    )
    .unwrap();
    assert!(wait.rounds > 0 && cut.rounds > 0);
    assert!(wait.dropped_total > 0, "{wait:?}");
    assert!(
        cut.round_p99.unwrap() < wait.round_p99.unwrap(),
        "deadline cutoff must cap the stall: cut {:?} vs wait {:?}",
        cut.round_p99,
        wait.round_p99
    );
}

/// The ISSUE-9 async acceptance run: FedBuff-style buffered folding
/// completes **strictly more aggregated logical rounds than
/// synchronous wait-all within the same virtual-time horizon** on an
/// engineered flaky population.
///
/// Construction (probed, not tuned): k=2 of 8 over one always-up
/// client and 7 flaky ones whose 60 s up-windows are far shorter than
/// a round. Availability-aware selection puts the stable client plus
/// one doomed flaky pick in every cohort, so each synchronous wait-all
/// round stalls at the server's 3× give-up timeout. The sync probe
/// measures that makespan; the async run gets exactly that much
/// virtual time. With no barrier, the stable client redispatches the
/// moment its delta folds (buffer_k = 1 closes a logical round per
/// fold) while flaky give-up timers burn in the background — roughly
/// 3× the logical-round rate, asserted only as *strictly more*.
#[test]
fn async_buffered_completes_strictly_more_rounds_than_wait_all() {
    const ROUNDS: usize = 6;
    let horizon_gen = 240.0 * 3600.0;
    let (clients, traces) = flaky_population(8, horizon_gen, 60.0, 0.5);
    let base = FedOptions {
        rounds: ROUNDS,
        clients: 8,
        k: 2,
        select: "availability".into(),
        straggler: "wait-all".into(),
        jitter: 0.0,
        buffer_k: 1,
        ..Default::default()
    };

    // sync probe: every round aggregates the stable client and drops
    // its flaky co-pick after the 3x dropout-detection stall
    let sync = simulate_fed_with(&clients, &traces, &base).unwrap();
    assert_eq!(sync.rounds, ROUNDS, "sync probe must complete: {sync:?}");
    assert_eq!(sync.dropped_total, ROUNDS, "every round drops its flaky pick: {sync:?}");
    assert!(
        sync.makespan * 1.1 < horizon_gen,
        "traces must cover the run: {} vs {horizon_gen}",
        sync.makespan
    );
    assert_eq!(sync.staleness_p50, None, "sync deltas are never stale: {sync:?}");

    // same population, same virtual-time budget, async buffered folding
    let async_m = simulate_fed_with(
        &clients,
        &traces,
        &FedOptions {
            agg_mode: AggregationMode::Async,
            rounds: ROUNDS * 100, // horizon-bound, not round-bound
            horizon: sync.makespan,
            ..base.clone()
        },
    )
    .unwrap();
    assert!(
        async_m.rounds > sync.rounds,
        "async buffered folding must close strictly more logical rounds \
         in the same horizon: async {} vs wait-all {}",
        async_m.rounds,
        sync.rounds
    );
    assert!(async_m.staleness_p50.is_some(), "async runs report staleness: {async_m:?}");
    assert!(
        async_m.rounds_per_hour > sync.rounds_per_hour,
        "barrier-free folding must raise effective throughput: {} vs {}",
        async_m.rounds_per_hour,
        sync.rounds_per_hour
    );
    assert_eq!(
        async_m.aggregated_total + async_m.dropped_total,
        async_m.selected_total,
        "selection outcomes must partition in async mode too: {async_m:?}"
    );
}

/// Sync mode is completely untouched by the async knobs: whatever
/// `buffer_k` says, a sync run is bit-identical to the default-options
/// run (the pre-async behavior) and never reports staleness.
#[test]
fn sync_mode_ignores_async_knobs_bit_for_bit() {
    let base = FedOptions {
        rounds: 4,
        clients: 12,
        k: 4,
        trace: FedTraceKind::Flaky,
        ..Default::default()
    };
    let a = simulate_fed(&base).unwrap();
    assert_eq!(a.staleness_p50, None, "{a:?}");
    for buffer_k in [1usize, 3, 64] {
        let b = simulate_fed(&FedOptions { buffer_k, ..base.clone() }).unwrap();
        assert_eq!(a, b, "buffer_k {buffer_k} leaked into a sync run");
    }
}

#[derive(Debug)]
struct FedCase {
    seed: u64,
    rounds: usize,
}

/// Same options ⇒ bit-identical `FedMetrics` for **every registered
/// selection × straggler combination** — the engine must be a pure
/// function of its options (the ISSUE-5 determinism acceptance).
#[test]
fn fed_is_bit_identical_across_every_policy_combination() {
    let selections = SelectionRegistry::with_defaults();
    let stragglers = StragglerRegistry::with_defaults();
    forall(
        0xFED5EED,
        2,
        |g| FedCase {
            seed: 1 + g.int(0, 1_000_000) as u64 * 2_654_435_761,
            rounds: 4 + g.int(0, 4),
        },
        |case| {
            for select in selections.names() {
                for straggler in stragglers.names() {
                    let opts = FedOptions {
                        rounds: case.rounds,
                        clients: 12,
                        k: 4,
                        select: select.to_string(),
                        straggler: straggler.to_string(),
                        seed: case.seed,
                        trace: FedTraceKind::Flaky,
                        ..Default::default()
                    };
                    let a = simulate_fed(&opts).map_err(|e| e.to_string())?;
                    let b = simulate_fed(&opts).map_err(|e| e.to_string())?;
                    check(
                        a == b,
                        format!("{select} x {straggler} diverged:\n  {a:?}\n  {b:?}"),
                    )?;
                }
            }
            Ok(())
        },
    );
}

/// Async mode is itself bit-deterministic for the same options, across
/// **every registered selection policy** and multiple buffer sizes
/// (the straggler barrier is bypassed in async mode, so selection is
/// the policy axis that matters) — the ISSUE-9 determinism acceptance.
#[test]
fn fed_async_is_bit_identical_across_every_selection_policy() {
    let selections = SelectionRegistry::with_defaults();
    forall(
        0xA5FED_5EED,
        2,
        |g| FedCase {
            seed: 1 + g.int(0, 1_000_000) as u64 * 2_654_435_761,
            rounds: 4 + g.int(0, 4),
        },
        |case| {
            for select in selections.names() {
                for buffer_k in [1usize, 3] {
                    let opts = FedOptions {
                        rounds: case.rounds,
                        clients: 12,
                        k: 4,
                        select: select.to_string(),
                        agg_mode: AggregationMode::Async,
                        buffer_k,
                        seed: case.seed,
                        trace: FedTraceKind::Flaky,
                        ..Default::default()
                    };
                    let a = simulate_fed(&opts).map_err(|e| e.to_string())?;
                    let b = simulate_fed(&opts).map_err(|e| e.to_string())?;
                    check(
                        a == b,
                        format!("async {select} x buffer_k {buffer_k} diverged:\n  {a:?}\n  {b:?}"),
                    )?;
                    check(
                        a.aggregated_total + a.dropped_total == a.selected_total,
                        format!("async outcomes must partition selections: {a:?}"),
                    )?;
                }
            }
            Ok(())
        },
    );
}

/// Fair-share selection balances participation on an always-up
/// population: with K dividing the population evenly, round-robin
/// participation is exact and the Jain index is 1.0.
#[test]
fn fair_share_balances_participation_perfectly() {
    let clients: Vec<FedClient> =
        (0..8).map(|i| FedClient::new(i, DeviceKind::NanoH, 256, 1)).collect();
    let traces = vec![ClientTrace::always_up(); 8];
    let opts = FedOptions {
        rounds: 8,
        clients: 8,
        k: 4,
        select: "fair".into(),
        ..Default::default()
    };
    let m = simulate_fed_with(&clients, &traces, &opts).unwrap();
    assert_eq!(m.rounds, 8);
    assert!(
        (m.participation_fairness - 1.0).abs() < 1e-12,
        "8 rounds x K=4 over 8 clients must round-robin exactly: {m:?}"
    );
    for c in &m.per_client {
        assert_eq!(c.aggregated, 4, "client {}: {m:?}", c.id);
    }
}

fn run_registry(name: &str) -> Report {
    ExperimentRegistry::with_defaults()
        .run(name, &ExpContext::new())
        .unwrap_or_else(|e| panic!("{name}: {e:#}"))
}

/// `pacpp exp run fed --format json` acceptance shape: every selection
/// × straggler combination present, round-time/bytes/fairness columns,
/// and a lossless JSON round-trip.
#[test]
fn fed_experiment_covers_grid_and_roundtrips_json() {
    let rep = run_registry("fed");
    let distinct = |col: &str| {
        let mut v: Vec<String> = (0..rep.n_rows())
            .filter_map(|i| rep.cell(i, col).and_then(Cell::as_str).map(String::from))
            .collect();
        v.sort();
        v.dedup();
        v
    };
    assert_eq!(distinct("select").len(), 5, "selects: {:?}", distinct("select"));
    assert_eq!(distinct("mode"), vec!["async", "sync"], "modes: {:?}", distinct("mode"));
    assert_eq!(distinct("straggler").len(), 3, "stragglers: {:?}", distinct("straggler"));
    for col in ["rounds", "p50", "p95", "p99", "bytes_up", "bytes_down", "fairness"] {
        assert!(rep.columns().iter().any(|c| c.name == col), "missing {col}");
    }
    for i in 0..rep.n_rows() {
        let rounds = rep.cell(i, "rounds").unwrap().as_f64().unwrap();
        assert!(rounds > 0.0, "row {i} completed nothing");
    }

    let json = rep.render(Format::Json);
    let back = Report::from_json(&Json::parse(&json).expect("valid json")).expect("report");
    assert_eq!(back, rep, "JSON round-trip must be lossless");
}

/// The `fed_select` grid reports availability effects somewhere: the
/// flaky-trace rows drop strictly more client-rounds than the
/// stable-trace rows in aggregate.
#[test]
fn fed_select_experiment_shows_availability_effects() {
    let rep = run_registry("fed_select");
    let dropped_on = |trace: &str| -> f64 {
        (0..rep.n_rows())
            .filter(|&i| rep.cell(i, "trace").and_then(Cell::as_str) == Some(trace))
            .filter_map(|i| rep.cell(i, "dropped").and_then(Cell::as_f64))
            .sum()
    };
    assert!(
        dropped_on("flaky") > dropped_on("stable"),
        "flaky clients must drop more: flaky {} vs stable {}",
        dropped_on("flaky"),
        dropped_on("stable")
    );
}
