//! Property tests (via `util::prop`) for cross-module invariants:
//! `exec::partition_layers` (the pipelined engine's stage splitter),
//! the fleet event loop's same-seed determinism, the scaling-path
//! equivalences (calendar event queue vs binary heap, incremental vs
//! legacy dispatch, fed quoting shards), the fed `ClientTrace`
//! boundary semantics at exact toggle instants, the EASY-backfill
//! no-head-delay guarantee, the bounded-loss checkpoint arithmetic,
//! the Jain fairness index range, the in-sim DQN training loop's
//! same-config bit-determinism, the `cluster::Network`
//! collective-timing edge cases (n = 0/1, zero bytes, monotonicity),
//! and the observability layer's non-interference contract (tracing
//! on vs off is metric-identical; trace exports round-trip through
//! `util::json` and reconcile with the `events` counter).

use pacpp::cluster::{Env, Network};
use pacpp::exec::partition_layers;
use pacpp::fed::{
    simulate_fed, simulate_fed_observed, AggregationMode, ClientTrace, FedOptions, FedTraceKind,
};
use pacpp::fleet::{
    generate_churn, generate_jobs, jain_index, simulate_fleet, simulate_fleet_observed,
    AttemptTimeline, BestFit, CheckpointSpec, EventQueueKind, FleetMetrics, FleetOptions,
    PlacementPolicy, PreemptReplan, TraceKind,
};
use pacpp::learn::{evaluate, train, DqnConfig, LearnedQueue, TrainConfig};
use pacpp::obs::analyze::{analyze, summary_report, TraceDoc};
use pacpp::obs::Observer;
use pacpp::util::json::Json;
use pacpp::util::prop::{check, forall};
use pacpp::util::write_creating_dirs;

#[derive(Debug)]
struct SplitCase {
    layers: usize,
    stages: usize,
    available: Vec<usize>,
}

/// The even split `partition_layers` must produce when it succeeds.
fn expected_sizes(layers: usize, stages: usize) -> Vec<usize> {
    let base = layers / stages;
    let rem = layers % stages;
    (0..stages).map(|i| base + usize::from(i < rem)).collect()
}

#[test]
fn partition_layers_invariants() {
    forall(
        0xBEEF,
        120,
        |g| {
            let layers = g.int(1, 40);
            let stages = g.int(0, layers + 3);
            // random artifact inventory; sometimes seed in the exact
            // needed sizes so the Ok path is exercised too
            let mut available: Vec<usize> =
                (1..=layers.min(12)).filter(|_| g.bool()).collect();
            if g.bool() && stages >= 1 && stages <= layers {
                available.extend(expected_sizes(layers, stages));
            }
            available.sort_unstable();
            available.dedup();
            SplitCase { layers, stages, available }
        },
        |case| {
            let SplitCase { layers, stages, available } = case;
            match partition_layers(*layers, *stages, available) {
                Ok(sizes) => {
                    check(
                        *stages >= 1 && *stages <= *layers,
                        format!("accepted infeasible stage count {stages} for {layers}"),
                    )?;
                    check(
                        sizes.len() == *stages,
                        format!("{} spans != {stages} stages", sizes.len()),
                    )?;
                    check(
                        sizes.iter().sum::<usize>() == *layers,
                        format!("spans sum to {} != {layers}", sizes.iter().sum::<usize>()),
                    )?;
                    check(
                        sizes.iter().all(|s| available.contains(s)),
                        format!("span size outside available: {sizes:?} vs {available:?}"),
                    )?;
                    let mn = *sizes.iter().min().unwrap();
                    let mx = *sizes.iter().max().unwrap();
                    check(mx - mn <= 1, format!("uneven split {sizes:?}"))
                }
                Err(_) => {
                    // an error must be genuine: either the stage count
                    // is infeasible, or a required span size has no
                    // artifact
                    if *stages == 0 || *stages > *layers {
                        return Ok(());
                    }
                    let needed = expected_sizes(*layers, *stages);
                    check(
                        needed.iter().any(|s| !available.contains(s)),
                        format!(
                            "spurious error: {layers} layers / {stages} stages \
                             with all of {needed:?} in {available:?}"
                        ),
                    )
                }
            }
        },
    );
}

#[derive(Debug)]
struct FleetCase {
    seed: u64,
    n_jobs: usize,
}

/// Same seed ⇒ bit-identical `FleetMetrics`, churn and replans
/// included: the event loop must be a pure function of its inputs.
#[test]
fn fleet_event_loop_is_deterministic() {
    let env = Env::env_b();
    let opts = FleetOptions::default();
    forall(
        0xF1EE7,
        3,
        |g| FleetCase { seed: 1 + g.int(0, 1_000_000) as u64 * 2_654_435_761, n_jobs: g.int(5, 10) },
        |case| {
            let jobs = generate_jobs(TraceKind::Bursty, case.n_jobs, case.seed);
            let churn = generate_churn(&env, opts.horizon, 3.0, case.seed);
            let a = simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &opts)
                .map_err(|e| e.to_string())?;
            let b = simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &opts)
                .map_err(|e| e.to_string())?;
            check(a == b, format!("same-seed runs diverged:\n  {a:?}\n  {b:?}"))
        },
    );
}

/// Same `(env, config)` ⇒ bit-identical training: the whole episode
/// curve (decisions taken, rewards, ε, fitted-Q losses), the trained
/// weight dump, and the exported policy's held-out evaluation all
/// match across two independent runs. This is the learn subsystem's
/// reproducibility contract: a run is a pure function of its config.
#[test]
fn learn_training_is_bit_deterministic() {
    let env = Env::env_a();
    let cfg = TrainConfig {
        episodes: 4,
        jobs: 10,
        eval_seeds: 1,
        // a small replay gate so the SGD path actually runs at this size
        dqn: DqnConfig {
            min_replay: 16,
            batch: 8,
            batches_per_episode: 4,
            ..DqnConfig::default()
        },
        ..TrainConfig::default()
    };
    let a = train(&env, &cfg).expect("train a");
    let b = train(&env, &cfg).expect("train b");
    assert_eq!(a.episodes, b.episodes, "episode curves diverged");
    assert!(
        a.episodes.iter().any(|e| e.loss.is_some()),
        "config was meant to exercise the SGD path"
    );
    assert_eq!(
        a.net.to_json().to_string_pretty(),
        b.net.to_json().to_string_pretty(),
        "weight dumps diverged"
    );
    let ea = evaluate(&env, &cfg, &LearnedQueue::new(a.net)).expect("eval a");
    let eb = evaluate(&env, &cfg, &LearnedQueue::new(b.net)).expect("eval b");
    assert_eq!(ea, eb, "held-out decisions diverged");
}

#[derive(Debug)]
struct EquivCase {
    seed: u64,
    n_jobs: usize,
    queue: &'static str,
    churn: bool,
}

/// Drop the observe counters that legitimately differ between the
/// legacy and incremental dispatch paths (the caches exist exactly to
/// skip oracle calls); every simulated outcome stays.
fn scrub_counters(mut m: FleetMetrics) -> FleetMetrics {
    m.oracle_hits = 0;
    m.oracle_misses = 0;
    m.rescans_avoided = 0;
    m
}

/// The scaling paths must never change a run: the calendar event queue
/// is bit-identical to the binary heap (full equality — same dispatch
/// path, counters included), and the incremental dispatch index is
/// bit-identical to the legacy full-rescan policies once the observe
/// counters are scrubbed. Swept across queue discipline × placement
/// policy × churn.
#[test]
fn scaling_paths_are_bit_identical() {
    let env = Env::env_b();
    const QUEUES: [&str; 5] = ["fifo", "backfill", "sjf", "edf", "llf"];
    forall(
        0xEC4B1,
        5,
        |g| EquivCase {
            seed: 1 + g.int(0, 1_000_000) as u64 * 2_654_435_761,
            n_jobs: g.int(5, 9),
            queue: QUEUES[g.int(0, QUEUES.len() - 1)],
            churn: g.bool(),
        },
        |case| {
            let jobs = generate_jobs(TraceKind::Bursty, case.n_jobs, case.seed);
            let base = FleetOptions { queue: case.queue.into(), ..Default::default() };
            let churn = if case.churn {
                generate_churn(&env, base.horizon, 3.0, case.seed)
            } else {
                Vec::new()
            };
            let heap_inc = FleetOptions { event_queue: EventQueueKind::Heap, ..base.clone() };
            let legacy = FleetOptions { incremental_queue: false, ..heap_inc.clone() };
            for policy in [&BestFit as &dyn PlacementPolicy, &PreemptReplan] {
                let a = simulate_fleet(&env, &jobs, &churn, policy, &base)
                    .map_err(|e| e.to_string())?;
                let b = simulate_fleet(&env, &jobs, &churn, policy, &heap_inc)
                    .map_err(|e| e.to_string())?;
                check(
                    a == b,
                    format!("{}/{}: calendar diverged from heap", policy.name(), case.queue),
                )?;
                let c = simulate_fleet(&env, &jobs, &churn, policy, &legacy)
                    .map_err(|e| e.to_string())?;
                check(
                    scrub_counters(a) == scrub_counters(c),
                    format!(
                        "{}/{}: incremental dispatch diverged from legacy",
                        policy.name(),
                        case.queue
                    ),
                )?;
            }
            Ok(())
        },
    );
}

/// The fed quoting-pass shard count never changes the metrics: quotes
/// are pure per client and the oracle counters are computed
/// shard-invariantly.
#[test]
fn fed_shard_count_is_metric_invariant() {
    forall(
        0x54A8D,
        4,
        |g| (1 + g.int(0, 1_000_000) as u64 * 0x9E3779B9, g.int(8, 20)),
        |&(seed, clients)| {
            let base = FedOptions {
                rounds: 5,
                clients,
                k: 4,
                seed,
                trace: FedTraceKind::Flaky,
                ..Default::default()
            };
            let a = simulate_fed(&base).map_err(|e| e.to_string())?;
            for shards in [2, clients] {
                let b = simulate_fed(&FedOptions { shards, ..base.clone() })
                    .map_err(|e| e.to_string())?;
                check(a == b, format!("shards={shards} changed the metrics"))?;
            }
            Ok(())
        },
    );
}

#[derive(Debug)]
struct TraceCase {
    start_up: bool,
    toggles: Vec<f64>,
    horizon: f64,
}

/// `ClientTrace` boundary semantics at the *exact* toggle instants:
/// the state changes at the flip (a toggle at `t` belongs to the new
/// state, closed-open intervals), and the three views the round
/// engines consume — `available_at`, `up_remaining`,
/// `next_toggle_after` — agree with the flip-parity ground truth at
/// every probe: just before, exactly on, and just after each toggle.
/// Pins the off-by-one-window bug class the ISSUE-9 `up_remaining`
/// fix closed.
#[test]
fn client_trace_views_agree_at_exact_toggle_instants() {
    forall(
        0x7066_1E5,
        60,
        |g| {
            let n = g.int(1, 8);
            let mut toggles = Vec::new();
            let mut t = 0.0;
            for _ in 0..n {
                t += g.f64(0.5, 10.0);
                toggles.push(t);
            }
            TraceCase { start_up: g.bool(), toggles, horizon: t + g.f64(0.5, 10.0) }
        },
        |case| {
            let trace = ClientTrace::new(case.start_up, case.toggles.clone(), case.horizon);
            for (i, &tog) in case.toggles.iter().enumerate() {
                let eps = 1e-7; // far below the >= 0.5 inter-toggle gap
                for t in [tog - eps, tog, tog + eps] {
                    // ground truth: parity of flips at or before t
                    let flips = case.toggles.iter().filter(|&&x| x <= t).count();
                    let expect_up = case.start_up ^ (flips % 2 == 1);
                    let expect_next = case.toggles.iter().copied().find(|&x| x > t);
                    check(
                        trace.available_at(t) == expect_up,
                        format!(
                            "available_at({t}) != flip parity ({flips} flips) at toggle {i}"
                        ),
                    )?;
                    check(
                        trace.next_toggle_after(t) == expect_next,
                        format!(
                            "next_toggle_after({t}) = {:?}, expected {expect_next:?}",
                            trace.next_toggle_after(t)
                        ),
                    )?;
                    let rem = trace.up_remaining(t);
                    if expect_up {
                        let expect_rem = expect_next.map_or(f64::INFINITY, |x| x - t);
                        check(
                            rem == expect_rem,
                            format!("up_remaining({t}) = {rem}, expected {expect_rem}"),
                        )?;
                        check(rem > 0.0, format!("up at {t} yet zero headroom"))?;
                    } else {
                        check(rem == 0.0, format!("down at {t} yet up_remaining = {rem}"))?;
                    }
                }
            }
            Ok(())
        },
    );
}

/// EASY-backfill never delays the head job's start vs FIFO on the same
/// seed (churn-free, where finish estimates are exact).
///
/// The checkable form: under FIFO, jobs start in arrival order, so the
/// first job whose start exceeds its arrival is the first *blocked
/// head* — up to that blockage both disciplines behave identically,
/// and EASY's conservative rule (backfill only what provably finishes
/// by the head's shadow time) guarantees that job starts no later
/// under backfill. If no job was ever delayed, the runs must be
/// bit-identical (no blocked head means no backfill opportunity).
#[test]
fn backfill_never_delays_the_first_blocked_head() {
    let env = Env::nanos(2); // tiny pool: queueing is certain
    forall(
        0xBACF,
        3,
        |g| FleetCase { seed: 1 + g.int(0, 1_000_000) as u64 * 0x9E3779B9, n_jobs: 5 + g.int(0, 4) },
        |case| {
            let jobs = generate_jobs(TraceKind::Steady, case.n_jobs, case.seed);
            let fifo_opts = FleetOptions { queue: "fifo".into(), ..Default::default() };
            let bf_opts = FleetOptions { queue: "backfill".into(), ..Default::default() };
            let fifo = simulate_fleet(&env, &jobs, &[], &BestFit, &fifo_opts)
                .map_err(|e| e.to_string())?;
            let bf = simulate_fleet(&env, &jobs, &[], &BestFit, &bf_opts)
                .map_err(|e| e.to_string())?;
            let first_blocked = fifo.per_job.iter().find(|j| {
                j.first_start.map(|s| s > j.arrival + 1e-9).unwrap_or(true)
            });
            match first_blocked {
                None => check(
                    fifo == bf,
                    "no job ever queued, yet the disciplines diverged".to_string(),
                ),
                Some(j) => {
                    let Some(fifo_start) = j.first_start else {
                        // FIFO never started it within the horizon:
                        // backfill cannot possibly have delayed it
                        return Ok(());
                    };
                    let bf_start = bf.per_job[j.id].first_start;
                    check(
                        bf_start.map(|s| s <= fifo_start + 1e-6).unwrap_or(false),
                        format!(
                            "backfill delayed blocked head {}: fifo start {fifo_start}, \
                             backfill start {bf_start:?}",
                            j.id
                        ),
                    )
                }
            }
        },
    );
}

#[derive(Debug)]
struct CkptCase {
    epochs: usize,
    k: usize,
    service: f64,
    cost: f64,
    migration: f64,
    prior: f64,
    p0: f64,
    active: f64,
}

/// Bounded loss: at any instant of any attempt, the gap between live
/// progress and the best durable resume point is at most one
/// checkpoint interval (`k/epochs` of the whole job) — the invariant
/// that makes checkpointed restarts cheap. Also pins the timeline's
/// basic sanity: monotone progress within [p0, 1], and a completed
/// attempt pays exactly its scheduled checkpoints.
#[test]
fn checkpoint_loss_is_bounded_by_one_interval() {
    forall(
        0xC4B7,
        150,
        |g| {
            let epochs = g.int(1, 9);
            let k = g.int(1, epochs + 1).min(epochs);
            let service = g.f64(10.0, 10_000.0);
            let cost = g.f64(0.0, service / 4.0);
            let migration = g.f64(0.0, 100.0);
            // resume point: a durable boundary (or 0), with the attempt
            // starting at most one interval past it — the invariant the
            // simulator maintains across replans and restarts. Half the
            // cases pin p0 exactly on the *next, non-durable* boundary:
            // the replan-cut-a-checkpoint-pause shape, which the attempt
            // must retake or a restart loses two intervals.
            let n_boundaries = (epochs - 1) / k;
            let m = g.int(0, n_boundaries + 1);
            let prior = (m * k) as f64 / epochs as f64;
            let next_b = ((m + 1) * k) as f64 / epochs as f64;
            let p0 = if g.bool() && (m + 1) * k < epochs {
                next_b // stalled mid-pause at a boundary that never became durable
            } else {
                let gap = ((k as f64 / epochs as f64).min(1.0 - prior)).max(0.0);
                prior + gap * g.f64(0.0, 0.999)
            };
            let spec = CheckpointSpec::new(k, cost);
            let duration =
                AttemptTimeline::new(p0, prior, migration, service, epochs, Some(&spec))
                    .duration();
            let active = g.f64(0.0, 1.3) * duration;
            CkptCase { epochs, k, service, cost, migration, prior, p0, active }
        },
        |case| {
            let spec = CheckpointSpec::new(case.k, case.cost);
            let tl = AttemptTimeline::new(
                case.p0,
                case.prior,
                case.migration,
                case.service,
                case.epochs,
                Some(&spec),
            );
            let point = tl.at(case.active);
            let interval = case.k as f64 / case.epochs as f64;
            check(
                point.progress >= case.p0 - 1e-9 && point.progress <= 1.0 + 1e-9,
                format!("progress {} outside [p0={}, 1]", point.progress, case.p0),
            )?;
            let half = tl.at(case.active * 0.5);
            check(
                half.progress <= point.progress + 1e-9,
                format!("progress not monotone: {} then {}", half.progress, point.progress),
            )?;
            if let Some(b) = point.last_ckpt {
                check(
                    b <= point.progress + 1e-9,
                    format!("durable point {b} ahead of progress {}", point.progress),
                )?;
            }
            let resume = point.last_ckpt.unwrap_or(0.0).max(case.prior);
            check(
                point.progress - resume <= interval + 1e-9,
                format!(
                    "restart would lose {} > one interval {interval}",
                    point.progress - resume
                ),
            )?;
            // run to (past) completion: full progress, every scheduled
            // checkpoint completed and paid
            let done = tl.at(tl.duration() * 1.01 + 1.0);
            check(
                done.progress >= 1.0 - 1e-9,
                format!("completed attempt at progress {}", done.progress),
            )?;
            check(
                done.ckpts == tl.checkpoints_total(),
                format!("paid {} of {} checkpoints", done.ckpts, tl.checkpoints_total()),
            )?;
            check(
                (done.ckpt_time - done.ckpts as f64 * case.cost).abs() < 1e-6,
                format!("ckpt_time {} != {} x {}", done.ckpt_time, done.ckpts, case.cost),
            )
        },
    );
}

/// Jain's index lands in (0, 1] for any non-negative service vector,
/// hits 1.0 exactly on uniform vectors, and a single-user fleet trace
/// is perfectly fair end-to-end.
#[test]
fn jain_fairness_index_range() {
    forall(
        0x7A17,
        200,
        |g| {
            let n = g.int(1, 12);
            (0..n)
                .map(|_| if g.bool() { g.f64(0.0, 100.0) } else { 0.0 })
                .collect::<Vec<f64>>()
        },
        |xs| {
            let j = jain_index(xs);
            check(
                j > 0.0 && j <= 1.0 + 1e-9,
                format!("jain({xs:?}) = {j} outside (0, 1]"),
            )?;
            let uniform = vec![7.5; xs.len()];
            check(
                (jain_index(&uniform) - 1.0).abs() < 1e-12,
                "uniform service must be perfectly fair".to_string(),
            )
        },
    );
}

#[derive(Debug)]
struct CollectiveCase {
    bytes: u64,
    n: usize,
}

/// `cluster::Network` collective timing: degenerate participant counts
/// (n = 0/1) are free for the symmetric collectives, zero-byte
/// transfers cost only latency (never negative, never NaN), and every
/// collective is monotone in both participant count and payload size —
/// the invariants the fed aggregation models lean on.
#[test]
fn network_collectives_edge_cases_and_monotonicity() {
    let nets = [Network::lan_1gbps(), Network::wifi_100mbps()];
    forall(
        0xC0113C7,
        150,
        |g| CollectiveCase {
            bytes: (g.int(0, 1_000_001) as u64) * (1 + g.int(0, 1000) as u64),
            n: g.int(0, 64),
        },
        |case| {
            let &CollectiveCase { bytes, n } = case;
            let symmetric: [fn(&Network, u64, usize) -> f64; 3] = [
                Network::allreduce_time,
                Network::allgather_time,
                Network::broadcast_time,
            ];
            let all: [fn(&Network, u64, usize) -> f64; 4] = [
                Network::allreduce_time,
                Network::allgather_time,
                Network::broadcast_time,
                Network::star_gather_time,
            ];
            for net in &nets {
                // n = 0 / 1: nothing to synchronize
                for f in symmetric {
                    check(f(net, bytes, 0) == 0.0, "collective at n=0 not free".to_string())?;
                    check(f(net, bytes, 1) == 0.0, "collective at n=1 not free".to_string())?;
                }
                check(net.star_gather_time(bytes, 0) == 0.0, "star at n=0 not free".to_string())?;
                // zero bytes: pure latency, finite and non-negative
                for t in [
                    net.allreduce_time(0, n),
                    net.allgather_time(0, n),
                    net.broadcast_time(0, n),
                    net.star_gather_time(0, n),
                    net.transfer_time(0),
                ] {
                    check(
                        t.is_finite() && t >= 0.0,
                        format!("zero-byte time {t} must be finite and non-negative"),
                    )?;
                }
                // monotone in participant count and in payload
                for f in all {
                    check(
                        f(net, bytes, n) <= f(net, bytes, n + 1) + 1e-12,
                        format!("not monotone in n at ({bytes}, {n})"),
                    )?;
                    check(
                        f(net, bytes, n) <= f(net, bytes + 1_000_000, n) + 1e-12,
                        format!("not monotone in bytes at ({bytes}, {n})"),
                    )?;
                    check(
                        f(net, bytes, n).is_finite() && f(net, bytes, n) >= 0.0,
                        format!("time not finite/non-negative at ({bytes}, {n})"),
                    )?;
                }
            }
            Ok(())
        },
    );
}

/// A single-user trace (few jobs share one submitter) reports Jain
/// fairness of exactly 1.0; a multi-user trace stays within (0, 1].
#[test]
fn fleet_fairness_matches_user_structure() {
    let env = Env::env_a();
    // n/5 users: a 4-job trace collapses to one user
    let single = generate_jobs(TraceKind::Bursty, 4, 7);
    assert!(single.iter().all(|j| j.user == 0));
    let m = simulate_fleet(&env, &single, &[], &BestFit, &FleetOptions::default()).unwrap();
    assert_eq!(m.fairness, 1.0);
    assert_eq!(m.per_user.len(), 1);

    let multi = generate_jobs(TraceKind::Steady, 20, 7);
    let m = simulate_fleet(&env, &multi, &[], &BestFit, &FleetOptions::default()).unwrap();
    assert!(m.per_user.len() > 1, "20 jobs over 4 users");
    assert!(m.fairness > 0.0 && m.fairness <= 1.0 + 1e-9, "{}", m.fairness);
}

/// Tracing is observation, not participation: running the same seed
/// with a fully-enabled [`Observer`] must leave every `FleetMetrics`
/// and `FedMetrics` field bit-identical to the untraced run.
#[test]
fn tracing_never_changes_the_metrics() {
    let env = Env::env_b();
    let opts = FleetOptions::default();
    forall(
        0x0B5E7,
        3,
        |g| FleetCase { seed: 1 + g.int(0, 1_000_000) as u64 * 2_654_435_761, n_jobs: g.int(5, 10) },
        |case| {
            let jobs = generate_jobs(TraceKind::Bursty, case.n_jobs, case.seed);
            let churn = generate_churn(&env, opts.horizon, 3.0, case.seed);
            let plain = simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &opts)
                .map_err(|e| e.to_string())?;
            let obs = Observer::enabled();
            let traced =
                simulate_fleet_observed(&env, &jobs, &churn, &PreemptReplan, &opts, &obs)
                    .map_err(|e| e.to_string())?;
            check(plain == traced, "tracing changed the fleet metrics".to_string())?;
            let (held, recorded, _) = obs.trace_counts();
            check(
                held > 0 && recorded > 0,
                "enabled observer recorded nothing on a fleet run".to_string(),
            )?;

            let fed_opts = FedOptions {
                rounds: 4,
                clients: 12,
                k: 4,
                seed: case.seed,
                trace: FedTraceKind::Flaky,
                ..Default::default()
            };
            let plain = simulate_fed(&fed_opts).map_err(|e| e.to_string())?;
            let traced = simulate_fed_observed(&fed_opts, &Observer::enabled())
                .map_err(|e| e.to_string())?;
            check(plain == traced, "tracing changed the fed metrics".to_string())?;

            // and the async buffered engine honors the same contract
            let async_opts =
                FedOptions { agg_mode: AggregationMode::Async, ..fed_opts.clone() };
            let plain = simulate_fed(&async_opts).map_err(|e| e.to_string())?;
            let traced = simulate_fed_observed(&async_opts, &Observer::enabled())
                .map_err(|e| e.to_string())?;
            check(plain == traced, "tracing changed the async fed metrics".to_string())
        },
    );
}

/// The exported Chrome trace round-trips through `util::json` and its
/// per-event instants reconcile with the metrics registry: with
/// `sample = 1` and an ample ring, the number of `sim.event` trace
/// events equals the run's `events` counter exactly.
#[test]
fn trace_export_round_trips_and_matches_the_event_counter() {
    let env = Env::env_a();
    let opts = FleetOptions::default();
    let jobs = generate_jobs(TraceKind::Steady, 25, 11);
    let churn = generate_churn(&env, opts.horizon, 2.0, 11);
    let obs = Observer::with(1, 1 << 20);
    let m = simulate_fleet_observed(&env, &jobs, &churn, &BestFit, &opts, &obs).unwrap();

    let path_buf = std::env::temp_dir()
        .join(format!("pacpp_trace_rt_{}", std::process::id()))
        .join("fleet_trace.json");
    let path = path_buf.to_str().unwrap();
    write_creating_dirs(path, &obs.to_chrome_json().to_string_pretty()).unwrap();
    let text = std::fs::read_to_string(path).unwrap();
    let parsed = Json::parse(&text).expect("exported trace must re-parse via util::json");

    let events = parsed
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("traceEvents array");
    let sim_events = events
        .iter()
        .filter(|ev| ev.get("cat").and_then(|c| c.as_str()) == Some("sim.event"))
        .count();
    assert_eq!(
        sim_events, m.events,
        "sim.event trace instants must equal the `events` counter"
    );
    // the export carries the reconciliation metadata alongside
    let recorded = parsed
        .get("otherData")
        .and_then(|o| o.get("recorded"))
        .and_then(|r| r.as_u64())
        .expect("otherData.recorded");
    let (held, obs_recorded, dropped) = obs.trace_counts();
    assert_eq!(recorded, obs_recorded);
    assert_eq!(dropped, 0, "ample ring must not overwrite");
    assert_eq!(held as u64, obs_recorded);
    assert!(sim_events <= held, "instants are a subset of held events");
    std::fs::remove_dir_all(path_buf.parent().unwrap()).unwrap();
}

/// `--trace-sample` thins the *event stream*, never the metrics
/// registry: summarizing the same seeded run traced at sample 1 vs
/// sample 3 must yield identical Metrics-derived aggregate counters
/// (the `counter_*` metadata of `summary_report`), even though the
/// span-derived rows legitimately differ.
#[test]
fn trace_summary_counters_are_sample_invariant() {
    let env = Env::env_a();
    let opts = FleetOptions::default();
    forall(
        0x5A11D,
        3,
        |g| FleetCase { seed: 1 + g.int(0, 1_000_000) as u64 * 2_654_435_761, n_jobs: g.int(8, 16) },
        |case| {
            let jobs = generate_jobs(TraceKind::Bursty, case.n_jobs, case.seed);
            let churn = generate_churn(&env, opts.horizon, 2.0, case.seed);
            let mut docs = Vec::new();
            for sample in [1u64, 3] {
                let obs = Observer::with(sample, 1 << 20);
                simulate_fleet_observed(&env, &jobs, &churn, &BestFit, &opts, &obs)
                    .map_err(|e| e.to_string())?;
                let text = obs.to_chrome_json().to_string_pretty();
                docs.push(TraceDoc::load(&text).map_err(|e| e.to_string())?);
            }
            let (full, thinned) = (&docs[0], &docs[1]);
            check(
                full.sample == Some(1) && thinned.sample == Some(3),
                "exports must carry their sampling knob".to_string(),
            )?;
            check(
                !full.counters.is_empty(),
                "traced fleet run must absorb metrics counters".to_string(),
            )?;
            check(
                full.counters == thinned.counters,
                format!(
                    "metrics counters must ignore --trace-sample: {:?} vs {:?}",
                    full.counters, thinned.counters
                ),
            )?;
            // and the rendered summaries agree on every counter_* entry
            let counters = |doc: &TraceDoc| {
                let report = summary_report(&analyze(doc));
                report
                    .meta
                    .iter()
                    .filter(|(k, _)| k.starts_with("counter_"))
                    .map(|(k, v)| (k.clone(), v.clone()))
                    .collect::<Vec<_>>()
            };
            check(
                counters(full) == counters(thinned),
                "summary_report counter_* metadata must be sample-invariant".to_string(),
            )?;
            // sanity: thinning cannot increase the held event count
            check(
                thinned.events.len() <= full.events.len(),
                "sample 3 held more events than sample 1".to_string(),
            )
        },
    );
}
