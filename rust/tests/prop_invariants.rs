//! Property tests (via `util::prop`) for cross-module invariants:
//! `exec::partition_layers` (the pipelined engine's stage splitter) and
//! the fleet event loop's same-seed determinism.

use pacpp::cluster::Env;
use pacpp::exec::partition_layers;
use pacpp::fleet::{
    generate_churn, generate_jobs, simulate_fleet, FleetOptions, PreemptReplan, TraceKind,
};
use pacpp::util::prop::{check, forall};

#[derive(Debug)]
struct SplitCase {
    layers: usize,
    stages: usize,
    available: Vec<usize>,
}

/// The even split `partition_layers` must produce when it succeeds.
fn expected_sizes(layers: usize, stages: usize) -> Vec<usize> {
    let base = layers / stages;
    let rem = layers % stages;
    (0..stages).map(|i| base + usize::from(i < rem)).collect()
}

#[test]
fn partition_layers_invariants() {
    forall(
        0xBEEF,
        120,
        |g| {
            let layers = g.int(1, 40);
            let stages = g.int(0, layers + 3);
            // random artifact inventory; sometimes seed in the exact
            // needed sizes so the Ok path is exercised too
            let mut available: Vec<usize> =
                (1..=layers.min(12)).filter(|_| g.bool()).collect();
            if g.bool() && stages >= 1 && stages <= layers {
                available.extend(expected_sizes(layers, stages));
            }
            available.sort_unstable();
            available.dedup();
            SplitCase { layers, stages, available }
        },
        |case| {
            let SplitCase { layers, stages, available } = case;
            match partition_layers(*layers, *stages, available) {
                Ok(sizes) => {
                    check(
                        *stages >= 1 && *stages <= *layers,
                        format!("accepted infeasible stage count {stages} for {layers}"),
                    )?;
                    check(
                        sizes.len() == *stages,
                        format!("{} spans != {stages} stages", sizes.len()),
                    )?;
                    check(
                        sizes.iter().sum::<usize>() == *layers,
                        format!("spans sum to {} != {layers}", sizes.iter().sum::<usize>()),
                    )?;
                    check(
                        sizes.iter().all(|s| available.contains(s)),
                        format!("span size outside available: {sizes:?} vs {available:?}"),
                    )?;
                    let mn = *sizes.iter().min().unwrap();
                    let mx = *sizes.iter().max().unwrap();
                    check(mx - mn <= 1, format!("uneven split {sizes:?}"))
                }
                Err(_) => {
                    // an error must be genuine: either the stage count
                    // is infeasible, or a required span size has no
                    // artifact
                    if *stages == 0 || *stages > *layers {
                        return Ok(());
                    }
                    let needed = expected_sizes(*layers, *stages);
                    check(
                        needed.iter().any(|s| !available.contains(s)),
                        format!(
                            "spurious error: {layers} layers / {stages} stages \
                             with all of {needed:?} in {available:?}"
                        ),
                    )
                }
            }
        },
    );
}

#[derive(Debug)]
struct FleetCase {
    seed: u64,
    n_jobs: usize,
}

/// Same seed ⇒ bit-identical `FleetMetrics`, churn and replans
/// included: the event loop must be a pure function of its inputs.
#[test]
fn fleet_event_loop_is_deterministic() {
    let env = Env::env_b();
    let opts = FleetOptions::default();
    forall(
        0xF1EE7,
        3,
        |g| FleetCase { seed: 1 + g.int(0, 1_000_000) as u64 * 2_654_435_761, n_jobs: g.int(5, 10) },
        |case| {
            let jobs = generate_jobs(TraceKind::Bursty, case.n_jobs, case.seed);
            let churn = generate_churn(&env, opts.horizon, 3.0, case.seed);
            let a = simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &opts)
                .map_err(|e| e.to_string())?;
            let b = simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &opts)
                .map_err(|e| e.to_string())?;
            check(a == b, format!("same-seed runs diverged:\n  {a:?}\n  {b:?}"))
        },
    );
}
