//! Golden tests for the experiment API redesign: the registry-run
//! [`Report`]s must be **value-identical** to the legacy typed-row
//! functions they replace (which are deprecated, kept for one release),
//! and every report must survive a lossless JSON round-trip through
//! `util::json`.
//!
//! The underlying computations are deterministic (the planner's
//! threaded σ-search is bit-identical to serial — PR 1 golden tests), so
//! cells are compared exactly, not within a tolerance.

#![allow(deprecated)]

use pacpp::exp::{self, Cell, ExpContext, ExperimentRegistry, Report};
use pacpp::util::json::Json;

fn run(name: &str) -> Report {
    ExperimentRegistry::with_defaults()
        .run(name, &ExpContext::new())
        .unwrap_or_else(|e| panic!("{name}: {e}"))
}

fn assert_roundtrips(report: &Report) {
    let pretty = report.to_json().to_string_pretty();
    let back = Report::from_json(&Json::parse(&pretty).expect("report json parses"))
        .expect("report json has report shape");
    assert_eq!(&back, report, "{}: JSON round-trip must be lossless", report.name);
}

fn str_cell<'a>(report: &'a Report, row: usize, col: &str) -> &'a str {
    report
        .cell(row, col)
        .and_then(Cell::as_str)
        .unwrap_or_else(|| panic!("{}: row {row} col {col} not a string", report.name))
}

#[test]
fn table5_report_matches_legacy_rows() {
    let report = run("table5");
    let legacy = exp::table5();
    assert_eq!(report.n_rows(), legacy.len());
    let tasks = ["MRPC", "STS-B", "SST-2", "QNLI"];
    for (i, row) in legacy.iter().enumerate() {
        assert_eq!(str_cell(&report, i, "model"), row.model);
        assert_eq!(str_cell(&report, i, "technique"), row.technique);
        assert_eq!(str_cell(&report, i, "system"), row.system);
        for (task, hours) in tasks.iter().zip(&row.hours) {
            let cell = report.cell(i, task).unwrap();
            match hours {
                Some(h) => assert_eq!(cell, &Cell::Float(*h), "row {i} {task}"),
                None => assert!(cell.is_missing(), "row {i} {task}: OOM maps to Missing"),
            }
        }
    }
    assert_roundtrips(&report);
}

#[test]
fn fig12_report_matches_legacy_rows() {
    let report = run("fig12");
    let legacy = exp::fig12();
    assert_eq!(report.n_rows(), legacy.len());
    for (i, row) in legacy.iter().enumerate() {
        assert_eq!(str_cell(&report, i, "model"), row.model);
        assert_eq!(str_cell(&report, i, "system"), row.system);
        assert_eq!(
            report.cell(i, "epochs").unwrap(),
            &Cell::Int(row.epochs as i64),
            "row {i}"
        );
        match row.hours {
            Some(h) => assert_eq!(report.cell(i, "hours").unwrap(), &Cell::Float(h), "row {i}"),
            None => assert!(report.cell(i, "hours").unwrap().is_missing(), "row {i}"),
        }
    }
    assert_roundtrips(&report);
}

#[test]
fn fig16_report_matches_legacy_rows() {
    let report = run("fig16");
    let legacy = exp::fig16();
    assert_eq!(report.n_rows(), legacy.len());
    for (i, row) in legacy.iter().enumerate() {
        assert_eq!(str_cell(&report, i, "model"), row.model);
        assert_eq!(str_cell(&report, i, "system"), row.system);
        assert_eq!(
            report.cell(i, "n_devices").unwrap(),
            &Cell::Int(row.n_devices as i64),
            "row {i}"
        );
        match row.throughput {
            Some(t) => {
                assert_eq!(report.cell(i, "throughput").unwrap(), &Cell::Float(t), "row {i}")
            }
            None => assert!(report.cell(i, "throughput").unwrap().is_missing(), "row {i}"),
        }
        match row.weight_mem {
            Some(w) => {
                assert_eq!(report.cell(i, "weight_mem").unwrap(), &Cell::Bytes(w), "row {i}")
            }
            None => assert!(report.cell(i, "weight_mem").unwrap().is_missing(), "row {i}"),
        }
    }
    assert_roundtrips(&report);
}

#[test]
fn sweep_report_roundtrips_in_every_format() {
    let report = run("sweep");
    assert_roundtrips(&report);
    // text and csv render without panicking and carry every row
    let text = report.to_text();
    let csv = report.to_csv();
    assert!(text.lines().count() >= report.n_rows());
    assert_eq!(csv.lines().count(), report.n_rows() + 1, "header + one line per row");
}

/// The fleet's per-user dimension survives serialization: `fleet_users`
/// rows carry integer user ids that round-trip losslessly through JSON
/// and land in the CSV header + rows.
#[test]
fn fleet_users_report_roundtrips_user_ids() {
    let report = run("fleet_users");
    assert!(report.n_rows() > 0);
    assert!(
        report.columns().iter().any(|c| c.name == "user"),
        "per-user rows need a user column"
    );
    let users: Vec<i64> = (0..report.n_rows())
        .map(|i| match report.cell(i, "user").unwrap() {
            Cell::Int(u) => *u,
            other => panic!("row {i}: user must be an Int cell, got {other:?}"),
        })
        .collect();
    let mut distinct = users.clone();
    distinct.sort_unstable();
    distinct.dedup();
    assert!(distinct.len() >= 2, "the bursty trace spans several users: {distinct:?}");

    // lossless JSON round-trip, user cells included
    assert_roundtrips(&report);
    let pretty = report.to_json().to_string_pretty();
    let back = Report::from_json(&Json::parse(&pretty).unwrap()).unwrap();
    let back_users: Vec<i64> = (0..back.n_rows())
        .map(|i| match back.cell(i, "user").unwrap() {
            Cell::Int(u) => *u,
            other => panic!("row {i}: user decayed to {other:?}"),
        })
        .collect();
    assert_eq!(back_users, users, "user ids must survive the JSON round-trip");

    // CSV: header carries the column, one line per row
    let csv = report.to_csv();
    let header = csv.lines().next().unwrap();
    assert!(header.split(',').any(|h| h == "user"), "csv header: {header}");
    assert_eq!(csv.lines().count(), report.n_rows() + 1);
}
