//! CLI-level tests for the observability consumers: `pacpp trace
//! summarize` and `pacpp bench record|compare|trend`, driven through
//! the real binary (`CARGO_BIN_EXE_pacpp`) exactly as CI invokes them.
//! Everything here runs on engineered or freshly generated artifacts
//! in a per-test temp directory — no network, no prebuilt fixtures.

use std::path::PathBuf;
use std::process::{Command, Output};

use pacpp::util::json::Json;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pacpp_obs_cli_{name}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn pacpp(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_pacpp"))
        .args(args)
        .output()
        .expect("pacpp binary runs")
}

fn assert_ok(out: &Output, what: &str) {
    assert!(
        out.status.success(),
        "{what} failed (status {:?}):\n{}",
        out.status.code(),
        String::from_utf8_lossy(&out.stderr)
    );
}

fn read_json(path: &std::path::Path) -> Json {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    Json::parse(&text).unwrap_or_else(|e| panic!("parsing {}: {e}", path.display()))
}

/// The engineered two-round JSONL trace from the `obs::analyze` unit
/// tests, written as a file: round 2 is the straggler, upload dominates.
fn engineered_trace(dir: &std::path::Path) -> PathBuf {
    let path = dir.join("trace.jsonl");
    let lines = [
        r#"{"ts": 0, "cat": "fed.round", "name": "select", "id": 1}"#,
        r#"{"ts": 0, "cat": "fed.round", "name": "upload", "id": 1, "dur": 5}"#,
        r#"{"ts": 5, "cat": "fed.round", "name": "aggregate", "id": 1, "dur": 1}"#,
        r#"{"ts": 10, "cat": "fed.round", "name": "select", "id": 2}"#,
        r#"{"ts": 10, "cat": "fed.round", "name": "upload", "id": 2, "dur": 20}"#,
        r#"{"ts": 30, "cat": "fed.round", "name": "aggregate", "id": 2, "dur": 2}"#,
        r#"{"recorded": 6, "dropped": 0}"#,
    ];
    std::fs::write(&path, lines.join("\n") + "\n").unwrap();
    path
}

#[test]
fn trace_summarize_pins_aggregates_and_straggler_attribution() {
    let dir = tmp("summarize");
    let trace = engineered_trace(&dir);
    let out_path = dir.join("summary.json");
    let out = pacpp(&[
        "trace",
        "summarize",
        trace.to_str().unwrap(),
        "--format",
        "json",
        "--out",
        out_path.to_str().unwrap(),
    ]);
    assert_ok(&out, "trace summarize");

    let reports = read_json(&out_path);
    let reports = reports.as_arr().expect("--section all emits an array");
    assert_eq!(reports.len(), 3, "summary + critical + gaps");

    // summary: three (cat, name) aggregates, coverage complete
    let summary = &reports[0];
    assert_eq!(summary.get("name").unwrap().as_str(), Some("trace_summary"));
    assert_eq!(summary.get("rows").unwrap().as_arr().unwrap().len(), 3);
    assert_eq!(summary.path_str("meta.recorded").unwrap().as_str(), Some("6"));
    assert_eq!(summary.path_str("meta.dropped").unwrap().as_str(), Some("0"));

    // critical: the straggler round and its dominant phase are named
    let critical = &reports[1];
    assert_eq!(critical.get("name").unwrap().as_str(), Some("trace_critical"));
    assert_eq!(
        critical.path_str("meta").unwrap().get("critical_fed.round").unwrap().as_str(),
        Some("2"),
        "round 2 (22 s) must out-rank round 1 (6 s)"
    );
    // row 0 = the straggler: id 2, dominant phase "upload" at 20 s
    assert_eq!(critical.path_str("rows[0][1]").unwrap().as_u64(), Some(2));
    assert_eq!(critical.path_str("rows[0][3]").unwrap().as_f64(), Some(22.0));
    assert_eq!(critical.path_str("rows[0][6]").unwrap().as_str(), Some("upload"));
    assert_eq!(critical.path_str("rows[0][7]").unwrap().as_f64(), Some(20.0));

    // gaps: one fed.round timeline, window 32, busy 28, gap 4
    let gaps = &reports[2];
    assert_eq!(gaps.get("name").unwrap().as_str(), Some("trace_gaps"));
    assert_eq!(gaps.path_str("rows[0][2]").unwrap().as_f64(), Some(32.0));
    assert_eq!(gaps.path_str("rows[0][4]").unwrap().as_f64(), Some(4.0));

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn trace_summarize_reads_a_real_fleet_export() {
    let dir = tmp("real_trace");
    let trace_path = dir.join("fleet_trace.json");
    let out = pacpp(&[
        "fleet",
        "--jobs",
        "6",
        "--policy",
        "fifo",
        "--trace-out",
        trace_path.to_str().unwrap(),
        "--format",
        "json",
        "--out",
        dir.join("fleet.json").to_str().unwrap(),
    ]);
    assert_ok(&out, "traced fleet run");

    let summary_path = dir.join("summary.json");
    let out = pacpp(&[
        "trace",
        "summarize",
        trace_path.to_str().unwrap(),
        "--section",
        "summary",
        "--format",
        "json",
        "--out",
        summary_path.to_str().unwrap(),
    ]);
    assert_ok(&out, "trace summarize on a real export");
    let summary = read_json(&summary_path);
    assert!(
        !summary.get("rows").unwrap().as_arr().unwrap().is_empty(),
        "a traced fleet run must produce span/instant aggregates"
    );
    // the Metrics-derived counters ride along from otherData.metrics
    assert!(
        summary.path_str("meta.counter_events").is_some(),
        "summary must carry the events counter"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn bench_record_then_compare_passes_on_its_own_baseline() {
    let dir = tmp("record_compare");
    let artifact = dir.join("BENCH_fleet.json");
    let out = pacpp(&[
        "fleet",
        "--jobs",
        "6",
        "--policy",
        "fifo",
        "--format",
        "json",
        "--out",
        artifact.to_str().unwrap(),
    ]);
    assert_ok(&out, "fleet artifact run");

    let history = dir.join("bench_history.jsonl");
    let baseline = dir.join("bench_baseline.json");
    let out = pacpp(&[
        "bench",
        "record",
        artifact.to_str().unwrap(),
        "--history",
        history.to_str().unwrap(),
        "--label",
        "seed",
        "--baseline-out",
        baseline.to_str().unwrap(),
    ]);
    assert_ok(&out, "bench record");
    assert!(history.exists(), "record must append the history file");
    let base = read_json(&baseline);
    let series = base.get("series").unwrap().as_obj().unwrap();
    assert!(!series.is_empty(), "a fleet report must yield gated series");
    assert!(
        series.keys().all(|k| !k.contains(".wall.") && !k.starts_with("bench.")),
        "wall-clock series must not be gated: {:?}",
        series.keys().collect::<Vec<_>>()
    );

    // the simulator is deterministic, so the same invocation compared
    // against its own recorded baseline passes with zero regressions
    let verdict_path = dir.join("verdict.json");
    let out = pacpp(&[
        "bench",
        "compare",
        artifact.to_str().unwrap(),
        "--baseline",
        baseline.to_str().unwrap(),
        "--format",
        "json",
        "--out",
        verdict_path.to_str().unwrap(),
    ]);
    assert_ok(&out, "bench compare vs own baseline");
    let verdict = read_json(&verdict_path);
    assert_eq!(verdict.path_str("meta.regressed").unwrap().as_str(), Some("0"));
    assert_eq!(verdict.path_str("meta.mode").unwrap().as_str(), Some("baseline"));

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn bench_compare_fails_on_an_injected_regression() {
    let dir = tmp("regression");
    let artifact = dir.join("BENCH_fleet.json");
    assert_ok(
        &pacpp(&[
            "fleet",
            "--jobs",
            "6",
            "--policy",
            "fifo",
            "--format",
            "json",
            "--out",
            artifact.to_str().unwrap(),
        ]),
        "fleet artifact run",
    );
    let baseline = dir.join("base.json");
    assert_ok(
        &pacpp(&[
            "bench",
            "record",
            artifact.to_str().unwrap(),
            "--history",
            dir.join("h.jsonl").to_str().unwrap(),
            "--baseline-out",
            baseline.to_str().unwrap(),
        ]),
        "bench record",
    );

    // inject a regression: force one series' reference far above the
    // current value and pin its direction to higher-is-better
    let mut base = read_json(&baseline);
    let injected_series;
    {
        let Json::Obj(top) = &mut base else { panic!("baseline is an object") };
        let Some(Json::Obj(series)) = top.get_mut("series") else {
            panic!("baseline.series is an object")
        };
        let name = series.keys().next().unwrap().clone();
        let Some(Json::Obj(spec)) = series.get_mut(&name) else { panic!("series spec") };
        let current = spec.get("value").unwrap().as_f64().unwrap();
        spec.insert("value".to_string(), Json::from(current.abs() * 10.0 + 100.0));
        spec.insert("better".to_string(), Json::from("higher"));
        injected_series = name;
    }
    let injected = dir.join("injected.json");
    std::fs::write(&injected, base.to_string_pretty() + "\n").unwrap();

    let out = pacpp(&[
        "bench",
        "compare",
        artifact.to_str().unwrap(),
        "--baseline",
        injected.to_str().unwrap(),
    ]);
    assert!(
        !out.status.success(),
        "an injected >tolerance regression must exit nonzero"
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("regressed") && stderr.contains(&injected_series),
        "the failure must name the regressed series {injected_series:?}:\n{stderr}"
    );
    // the verdict table is still emitted before the failing exit
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("FAIL"), "verdict table missing from stdout:\n{stdout}");

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn bench_history_mode_and_trend() {
    let dir = tmp("history");
    let artifact = dir.join("BENCH_fleet.json");
    assert_ok(
        &pacpp(&[
            "fleet",
            "--jobs",
            "6",
            "--policy",
            "fifo",
            "--format",
            "json",
            "--out",
            artifact.to_str().unwrap(),
        ]),
        "fleet artifact run",
    );
    let history = dir.join("h.jsonl");
    for label in ["c1", "c2"] {
        assert_ok(
            &pacpp(&[
                "bench",
                "record",
                artifact.to_str().unwrap(),
                "--history",
                history.to_str().unwrap(),
                "--label",
                label,
            ]),
            "bench record",
        );
    }

    // identical runs: newest vs median of priors regresses nothing
    let out = pacpp(&["bench", "compare", "--history", history.to_str().unwrap()]);
    assert_ok(&out, "bench compare --history on identical runs");

    let trend_path = dir.join("trend.json");
    let out = pacpp(&[
        "bench",
        "trend",
        "--history",
        history.to_str().unwrap(),
        "--format",
        "json",
        "--out",
        trend_path.to_str().unwrap(),
    ]);
    assert_ok(&out, "bench trend");
    let trend = read_json(&trend_path);
    let rows = trend.get("rows").unwrap().as_arr().unwrap();
    assert!(!rows.is_empty(), "trend must list the recorded series");

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn cli_rejects_bad_invocations() {
    // missing file
    let out = pacpp(&["trace", "summarize", "/nonexistent/trace.json"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("cannot read"));
    // unknown trace action
    assert!(!pacpp(&["trace", "frobnicate"]).status.success());
    // compare needs exactly one reference source
    let out = pacpp(&["bench", "compare", "--baseline", "a.json", "--history", "b.jsonl"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("exactly one"));
    let out = pacpp(&["bench", "compare"]);
    assert!(!out.status.success());
    // record with no files
    assert!(!pacpp(&["bench", "record"]).status.success());
    // unknown section
    let dir = tmp("bad_section");
    let trace = engineered_trace(&dir);
    let out = pacpp(&["trace", "summarize", trace.to_str().unwrap(), "--section", "nope"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown --section"));
    std::fs::remove_dir_all(&dir).unwrap();
}
