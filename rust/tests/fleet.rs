//! Fleet integration tests: the churn acceptance comparison
//! (preempt-and-replan must complete strictly more jobs than
//! FIFO-exclusive under the same churn trace) and end-to-end coverage
//! of the `fleet` experiment through the registry.

use pacpp::cluster::Env;
use pacpp::exp::{Cell, ExpContext, ExperimentRegistry, Format, Report};
use pacpp::fleet::{
    simulate_fleet, BestFit, ChurnEvent, ChurnKind, FifoExclusive, FleetOptions, Job,
    PreemptReplan,
};
use pacpp::model::ModelSpec;
use pacpp::util::json::Json;

/// Preempt-and-replan completes strictly more jobs than FIFO-exclusive
/// under the same churn trace — the structural reason multi-tenant
/// partitioning matters under churn: an exclusive job is exposed to
/// *every* device's churn, a partitioned job only to its own slice's.
///
/// Construction (no tuned constants): three identical T5-Base jobs
/// arrive at t=0 on Env.A, and device 3 leaves at 0.1·t1, where t1 is
/// the single-device service time measured by a probe run.
///
/// * Preempt (best-fit placement): each job runs on its own Nano and
///   finishes at exactly t1; the departing device 3 is idle, so no job
///   is touched. All 3 complete by any horizon > t1.
/// * FIFO-exclusive: job 0 holds all four devices, so the leave
///   restarts it from scratch at 0.1·t1 on the surviving three; the
///   three jobs then run serially at T3 each. Parallel speedup is
///   strictly sub-linear (AllReduce, pipeline bubbles, redistribution),
///   so 3·T3 > t1 and the last job finishes after 0.1·t1 + t1 — past a
///   1.05·t1 horizon.
#[test]
fn preempt_replan_beats_fifo_exclusive_under_churn() {
    let jobs: Vec<Job> =
        (0..3).map(|i| Job::new(i, 0.0, ModelSpec::t5_base(), 2048, 3)).collect();

    // probe: single-device service time of this job shape
    let probe_job = vec![Job::new(0, 0.0, ModelSpec::t5_base(), 2048, 3)];
    let probe = simulate_fleet(
        &Env::nanos(1),
        &probe_job,
        &[],
        &BestFit,
        &FleetOptions::default(),
    )
    .unwrap();
    assert_eq!(probe.completed, 1, "probe must complete: {probe:?}");
    let t1 = probe.makespan;
    assert!(t1 > 0.0);

    let env = Env::env_a();
    let churn = [ChurnEvent { time: 0.1 * t1, kind: ChurnKind::Leave(3) }];
    let opts = FleetOptions { horizon: 1.05 * t1, ..Default::default() };

    let pre = simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &opts).unwrap();
    let fifo = simulate_fleet(&env, &jobs, &churn, &FifoExclusive, &opts).unwrap();

    assert_eq!(pre.completed, 3, "partitioned jobs are untouched by the leave: {pre:?}");
    assert!(
        fifo.completed < pre.completed,
        "FIFO-exclusive must complete strictly fewer: fifo {fifo:?} vs preempt {pre:?}"
    );
    assert_eq!(fifo.restarts, 1, "the leave restarts the exclusive job: {fifo:?}");
    assert!(fifo.work_lost > 0.0);
    assert_eq!(pre.replans + pre.restarts, 0, "no preempt job was hit: {pre:?}");
}

/// Mid-job degrade of an assigned device: preempt-and-replan keeps the
/// progress (one replan, migration paid), restart policies lose it.
#[test]
fn degrade_replans_preempt_and_restarts_fifo() {
    let env = Env::env_a();
    // T5-Large needs >= 2 Nanos (weights alone exceed one 4 GB budget),
    // so the best-fit slice survives a degrade with the same memory.
    let jobs = vec![Job::new(0, 0.0, ModelSpec::t5_large(), 1024, 3)];
    let churn = [ChurnEvent { time: 120.0, kind: ChurnKind::Degrade(0) }];
    let opts = FleetOptions::default();

    let pre = simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &opts).unwrap();
    assert_eq!(pre.replans, 1, "{pre:?}");
    assert_eq!(pre.restarts, 0, "{pre:?}");
    assert!(pre.migration_overhead > 0.0);
    assert_eq!(pre.work_lost, 0.0);
    assert_eq!(pre.completed, 1);

    let fifo = simulate_fleet(&env, &jobs, &churn, &FifoExclusive, &opts).unwrap();
    assert_eq!(fifo.restarts, 1, "{fifo:?}");
    assert!((fifo.work_lost - 120.0).abs() < 1e-6, "{fifo:?}");
    assert_eq!(fifo.completed, 1);
}

fn run_registry(name: &str) -> Report {
    ExperimentRegistry::with_defaults()
        .run(name, &ExpContext::new())
        .unwrap_or_else(|e| panic!("{name}: {e:#}"))
}

/// `pacpp exp run fleet --format json` acceptance shape: >= 3 policies
/// x >= 2 traces x >= 2 envs, with throughput / p50 / p95 / p99 /
/// utilization columns, and a lossless JSON round-trip.
#[test]
fn fleet_experiment_covers_grid_and_roundtrips_json() {
    let rep = run_registry("fleet");
    let distinct = |col: &str| {
        let mut v: Vec<String> = (0..rep.n_rows())
            .filter_map(|i| rep.cell(i, col).and_then(Cell::as_str).map(String::from))
            .collect();
        v.sort();
        v.dedup();
        v
    };
    assert!(distinct("policy").len() >= 3, "policies: {:?}", distinct("policy"));
    assert!(distinct("trace").len() >= 2, "traces: {:?}", distinct("trace"));
    assert!(distinct("env").len() >= 2, "envs: {:?}", distinct("env"));
    for col in ["throughput", "p50", "p95", "p99", "utilization"] {
        assert!(rep.columns().iter().any(|c| c.name == col), "missing {col}");
    }
    // every cell simulated something: jobs arrived and were accounted for
    for i in 0..rep.n_rows() {
        let completed = rep.cell(i, "completed").unwrap().as_f64().unwrap();
        let failed = rep.cell(i, "failed").unwrap().as_f64().unwrap();
        let jobs = rep.cell(i, "jobs").unwrap().as_f64().unwrap();
        assert!(completed + failed <= jobs, "row {i}");
        assert!(completed > 0.0, "row {i} completed nothing");
    }

    let json = rep.render(Format::Json);
    let back = Report::from_json(&Json::parse(&json).expect("valid json")).expect("report");
    assert_eq!(back, rep, "JSON round-trip must be lossless");
}

/// The churn grid reports churn effects somewhere (replans on the
/// preempt rows, restarts + lost work on the restart-policy rows).
#[test]
fn fleet_churn_experiment_reports_churn_effects() {
    let rep = run_registry("fleet_churn");
    let sum = |col: &str| -> f64 {
        (0..rep.n_rows())
            .filter_map(|i| rep.cell(i, col).and_then(Cell::as_f64))
            .sum()
    };
    assert!(sum("replans") > 0.0);
    assert!(sum("restarts") > 0.0);
    assert!(sum("work_lost") > 0.0);
}
