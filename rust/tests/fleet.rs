//! Fleet integration tests: the churn acceptance comparison
//! (preempt-and-replan must complete strictly more jobs than
//! FIFO-exclusive under the same churn trace), the backfill-goodput
//! and bounded-loss-checkpoint acceptance scenarios, per-queue-policy
//! determinism, and end-to-end coverage of the fleet experiments
//! through the registry.
//!
//! The engineered scenarios follow the probe pattern: service times
//! are *measured* by probe runs, then churn times, deadlines and
//! horizons are constructed relative to them with wide margins — no
//! tuned constants, and the preconditions are asserted so a cost-model
//! change fails loudly at the probe, not mysteriously at the claim.

use pacpp::cluster::{DeviceKind, Env};
use pacpp::exp::{Cell, ExpContext, ExperimentRegistry, Format, Report};
use pacpp::fleet::{
    generate_churn, generate_jobs, simulate_fleet, BestFit, CheckpointSpec, ChurnEvent,
    ChurnKind, FifoExclusive, FleetOptions, Job, PreemptReplan, TraceKind,
};
use pacpp::model::ModelSpec;
use pacpp::util::json::Json;

/// Preempt-and-replan completes strictly more jobs than FIFO-exclusive
/// under the same churn trace — the structural reason multi-tenant
/// partitioning matters under churn: an exclusive job is exposed to
/// *every* device's churn, a partitioned job only to its own slice's.
///
/// Construction (no tuned constants): three identical T5-Base jobs
/// arrive at t=0 on Env.A, and device 3 leaves at 0.1·t1, where t1 is
/// the single-device service time measured by a probe run.
///
/// * Preempt (best-fit placement): each job runs on its own Nano and
///   finishes at exactly t1; the departing device 3 is idle, so no job
///   is touched. All 3 complete by any horizon > t1.
/// * FIFO-exclusive: job 0 holds all four devices, so the leave
///   restarts it from scratch at 0.1·t1 on the surviving three; the
///   three jobs then run serially at T3 each. Parallel speedup is
///   strictly sub-linear (AllReduce, pipeline bubbles, redistribution),
///   so 3·T3 > t1 and the last job finishes after 0.1·t1 + t1 — past a
///   1.05·t1 horizon.
#[test]
fn preempt_replan_beats_fifo_exclusive_under_churn() {
    let jobs: Vec<Job> =
        (0..3).map(|i| Job::new(i, 0.0, ModelSpec::t5_base(), 2048, 3)).collect();

    // probe: single-device service time of this job shape
    let probe_job = vec![Job::new(0, 0.0, ModelSpec::t5_base(), 2048, 3)];
    let probe = simulate_fleet(
        &Env::nanos(1),
        &probe_job,
        &[],
        &BestFit,
        &FleetOptions::default(),
    )
    .unwrap();
    assert_eq!(probe.completed, 1, "probe must complete: {probe:?}");
    let t1 = probe.makespan;
    assert!(t1 > 0.0);

    let env = Env::env_a();
    let churn = [ChurnEvent { time: 0.1 * t1, kind: ChurnKind::Leave(3) }];
    let opts = FleetOptions { horizon: 1.05 * t1, ..Default::default() };

    let pre = simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &opts).unwrap();
    let fifo = simulate_fleet(&env, &jobs, &churn, &FifoExclusive, &opts).unwrap();

    assert_eq!(pre.completed, 3, "partitioned jobs are untouched by the leave: {pre:?}");
    assert!(
        fifo.completed < pre.completed,
        "FIFO-exclusive must complete strictly fewer: fifo {fifo:?} vs preempt {pre:?}"
    );
    assert_eq!(fifo.restarts, 1, "the leave restarts the exclusive job: {fifo:?}");
    assert!(fifo.work_lost > 0.0);
    assert_eq!(pre.replans + pre.restarts, 0, "no preempt job was hit: {pre:?}");
}

/// Mid-job degrade of an assigned device: preempt-and-replan keeps the
/// progress (one replan, migration paid), restart policies lose it.
#[test]
fn degrade_replans_preempt_and_restarts_fifo() {
    let env = Env::env_a();
    // T5-Large needs >= 2 Nanos (weights alone exceed one 4 GB budget),
    // so the best-fit slice survives a degrade with the same memory.
    let jobs = vec![Job::new(0, 0.0, ModelSpec::t5_large(), 1024, 3)];
    let churn = [ChurnEvent { time: 120.0, kind: ChurnKind::Degrade(0) }];
    let opts = FleetOptions::default();

    let pre = simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &opts).unwrap();
    assert_eq!(pre.replans, 1, "{pre:?}");
    assert_eq!(pre.restarts, 0, "{pre:?}");
    assert!(pre.migration_overhead > 0.0);
    assert_eq!(pre.work_lost, 0.0);
    assert_eq!(pre.completed, 1);

    let fifo = simulate_fleet(&env, &jobs, &churn, &FifoExclusive, &opts).unwrap();
    assert_eq!(fifo.restarts, 1, "{fifo:?}");
    assert!((fifo.work_lost - 120.0).abs() < 1e-6, "{fifo:?}");
    assert_eq!(fifo.completed, 1);
}

/// EASY-backfill beats FIFO head-of-line queueing on goodput under a
/// bursty mixed-size trace, at equal seeds/inputs.
///
/// Construction (probed, not tuned): on a 2×Nano pool, a long small
/// job S0 holds one device; a big job B (T5-Large needs both Nanos)
/// blocks at the head until S0 finishes; three short jobs queue behind
/// B. Under FIFO they wait for S0 *and* B; under EASY they backfill
/// the idle second Nano — provably finishing before B's shadow time —
/// and meet deadlines FIFO misses. B itself starts at the same instant
/// either way (the no-head-delay property), so the comparison is pure
/// queueing discipline.
#[test]
fn backfill_beats_fifo_goodput_on_bursty_mixed_sizes() {
    // probes: single-device service of the short and long small-model
    // shapes, and the full-pool reference the deadline anchors on
    let probe = |env: &Env, job: Job, exclusive: bool| -> f64 {
        let jobs = vec![job];
        let m = if exclusive {
            simulate_fleet(env, &jobs, &[], &FifoExclusive, &FleetOptions::default())
        } else {
            simulate_fleet(env, &jobs, &[], &BestFit, &FleetOptions::default())
        }
        .unwrap();
        assert_eq!(m.completed, 1, "probe must complete");
        m.makespan
    };
    let one = Env::nanos(1);
    let two = Env::nanos(2);
    let short = |id, arrival| Job::new(id, arrival, ModelSpec::t5_base(), 512, 2);
    let long = |id| Job::new(id, 0.0, ModelSpec::t5_base(), 4096, 4);

    let t_short = probe(&one, short(0, 0.0), false);
    let t_long = probe(&one, long(0), false);
    // FIFO-exclusive takes the whole (= initial) pool, so its makespan
    // IS the oracle's full-pool quote — the deadline reference
    let ref_short = probe(&two, short(0, 0.0), true);

    // preconditions that make the margins wide, asserted not assumed
    assert!(t_long > 3600.0, "long job must run for hours, got {t_long}");
    assert!(
        240.0 + 3.0 * t_short < 0.5 * t_long,
        "short jobs (3x{t_short}s deadline) must fit well inside the long job ({t_long}s)"
    );

    // deadline = arrival + mult x ref_short = arrival + 3 x t_short
    let mult_short = 3.0 * t_short / ref_short;
    let jobs = vec![
        long(0).with_deadline_mult(100.0),
        Job::new(1, 60.0, ModelSpec::t5_large(), 1024, 2).with_deadline_mult(100.0),
        short(2, 120.0).with_deadline_mult(mult_short),
        short(3, 180.0).with_deadline_mult(mult_short),
        short(4, 240.0).with_deadline_mult(mult_short),
    ];

    let fifo_opts = FleetOptions { queue: "fifo".into(), ..Default::default() };
    let bf_opts = FleetOptions { queue: "backfill".into(), ..Default::default() };
    let fifo = simulate_fleet(&two, &jobs, &[], &BestFit, &fifo_opts).unwrap();
    let bf = simulate_fleet(&two, &jobs, &[], &BestFit, &bf_opts).unwrap();

    assert_eq!(fifo.completed, 5, "{fifo:?}");
    assert_eq!(bf.completed, 5, "{bf:?}");
    // the no-head-delay guarantee: B starts at the same instant
    assert_eq!(
        bf.per_job[1].first_start, fifo.per_job[1].first_start,
        "backfill must not move the blocked head's start"
    );
    // the goodput claim: the three shorts meet their deadline only
    // when they may jump the line
    assert_eq!(bf.deadline_met, 5, "{bf:?}");
    assert_eq!(fifo.deadline_met, 2, "shorts starve behind the head: {fifo:?}");
    assert!(
        bf.goodput_per_hour > fifo.goodput_per_hour,
        "EASY-backfill must win goodput: bf {} vs fifo {}",
        bf.goodput_per_hour,
        fifo.goodput_per_hour
    );
    assert!(bf.latency_p95.unwrap() < fifo.latency_p95.unwrap(), "{bf:?} {fifo:?}");
}

/// Checkpointing turns a fatal churn pattern into a completed job:
/// with `ckpt off` two pool replacements cost the whole attempt twice
/// and the horizon closes first; with `k=1` the job resumes from the
/// last epoch checkpoint and finishes — strictly more completions, the
/// ≥ acceptance bound with margin.
#[test]
fn checkpoint_k1_completes_at_least_as_many_as_off_under_churn() {
    let env = Env::nanos(1);
    let jobs = vec![Job::new(0, 0.0, ModelSpec::t5_base(), 2048, 4)];
    let probe = simulate_fleet(&env, &jobs, &[], &BestFit, &FleetOptions::default()).unwrap();
    assert_eq!(probe.completed, 1);
    let t1 = probe.makespan;

    // the pool's only device is swapped out twice mid-run
    let churn = vec![
        ChurnEvent { time: 0.55 * t1, kind: ChurnKind::Leave(0) },
        ChurnEvent { time: 0.55 * t1 + 1.0, kind: ChurnKind::Join(10, DeviceKind::NanoH) },
        ChurnEvent { time: 1.25 * t1, kind: ChurnKind::Leave(10) },
        ChurnEvent { time: 1.25 * t1 + 1.0, kind: ChurnKind::Join(11, DeviceKind::NanoH) },
    ];
    let horizon = 2.2 * t1;
    let off_opts = FleetOptions { horizon, ..Default::default() };
    let ck_opts = FleetOptions {
        horizon,
        ckpt: Some(CheckpointSpec::new(1, 1.0)),
        ..Default::default()
    };
    let off = simulate_fleet(&env, &jobs, &churn, &BestFit, &off_opts).unwrap();
    let ck = simulate_fleet(&env, &jobs, &churn, &BestFit, &ck_opts).unwrap();

    // off: restart at 0.55·t1 and again at 1.25·t1; the third attempt
    // needs until 2.25·t1+ — past the horizon
    assert_eq!(off.completed, 0, "{off:?}");
    assert_eq!(off.restarts, 2, "{off:?}");
    // ck: resume from the 0.50 checkpoint, finish around 1.05·t1 —
    // before the second churn event even lands on the (idle) pool
    assert_eq!(ck.completed, 1, "{ck:?}");
    assert_eq!(ck.restarts, 1, "{ck:?}");
    assert!(ck.completed >= off.completed, "the acceptance bound");
    assert!(ck.ckpt_count >= 2, "{ck:?}");
    assert!(ck.ckpt_overhead > 0.0);
    assert!(
        ck.work_lost <= t1 / 4.0 + 1e-6,
        "bounded loss: {} vs one epoch {}",
        ck.work_lost,
        t1 / 4.0
    );
    assert!(ck.work_lost < off.work_lost, "{ck:?} vs {off:?}");
}

/// Same-seed bit-identical determinism extends to every queue policy,
/// the deadline-aware disciplines included.
#[test]
fn every_queue_policy_is_deterministic() {
    let env = Env::env_b();
    let jobs = generate_jobs(TraceKind::Bursty, 12, 33);
    let churn = generate_churn(&env, 48.0 * 3600.0, 3.0, 33);
    for queue in ["fifo", "backfill", "sjf", "edf", "llf"] {
        let opts = FleetOptions { queue: queue.into(), ..Default::default() };
        let a = simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &opts).unwrap();
        let b = simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &opts).unwrap();
        assert_eq!(a, b, "queue {queue} diverged across identical runs");
        assert_eq!(a.completed + a.failed + a.incomplete, 12, "queue {queue}: {a:?}");
    }
}

/// The deadline-queueing acceptance scenario: EDF (and LLF) meet every
/// deadline FIFO meets on the same inputs, plus strictly more.
///
/// Note EDF cannot dominate FIFO per-job on *arbitrary* traces in a
/// non-preemptive setting (two jobs with nearly-equal deadlines are the
/// classic counterexample), so the pinned property is the engineered
/// form, constructed with probed margins:
///
/// A blocker occupies the single-device pool while a long loose-
/// deadline job (id 1) and a short tight-deadline job (id 2) queue
/// behind it. At the blocker's finish FIFO starts the long head first,
/// so the short job finishes at `t_b + t_long + t_short` — past its
/// deadline (precondition asserted); EDF/LLF start the short job first
/// and both jobs finish comfortably inside deadlines sized for exactly
/// that order.
#[test]
fn edf_meets_every_deadline_fifo_meets_plus_strictly_more() {
    let env = Env::nanos(1);
    let probe = |job: Job| -> f64 {
        let jobs = vec![Job { id: 0, arrival: 0.0, ..job }];
        let m = simulate_fleet(&env, &jobs, &[], &BestFit, &FleetOptions::default()).unwrap();
        assert_eq!(m.completed, 1, "probe must complete");
        m.makespan
    };
    let short_shape = |id, arrival| Job::new(id, arrival, ModelSpec::t5_base(), 512, 2);
    let long_shape = |id, arrival| Job::new(id, arrival, ModelSpec::t5_base(), 4096, 3);
    let t_short = probe(short_shape(0, 0.0));
    let t_long = probe(long_shape(0, 0.0));
    // preconditions: both arrivals land while the blocker still runs,
    // and FIFO's short-job finish provably overshoots its deadline
    assert!(t_short > 40.0, "blocker must outlive both arrivals: {t_short}");
    assert!(
        t_long > 20.0 + 0.2 * t_short,
        "FIFO must overshoot the short deadline: t_long {t_long}, t_short {t_short}"
    );

    // deadline = arrival + mult x single-device reference (the pool IS
    // one device, so the probe makespans are the oracle references)
    let jobs = vec![
        short_shape(0, 0.0).with_deadline_mult(100.0), // the blocker: never misses
        long_shape(1, 10.0).with_deadline_mult(1.2 * (2.0 * t_short + t_long) / t_long),
        short_shape(2, 20.0).with_deadline_mult(2.2),
    ];
    let run = |queue: &str| {
        simulate_fleet(
            &env,
            &jobs,
            &[],
            &BestFit,
            &FleetOptions { queue: queue.into(), ..Default::default() },
        )
        .unwrap()
    };
    let fifo = run("fifo");
    let edf = run("edf");
    let llf = run("llf");

    for m in [&fifo, &edf, &llf] {
        assert_eq!(m.completed, 3, "{m:?}");
    }
    // the short job's deadline really does sit between the two orders
    let d2 = fifo.per_job[2].deadline;
    assert!(d2.is_finite());
    assert!(fifo.per_job[2].finish.unwrap() > d2, "FIFO must miss the short job: {fifo:?}");

    assert_eq!(fifo.deadline_met, 2, "{fifo:?}");
    assert_eq!(edf.deadline_met, 3, "{edf:?}");
    assert_eq!(llf.deadline_met, 3, "{llf:?}");
    // the pinned form of the property: met(FIFO) ⊆ met(EDF/LLF)
    for j in 0..jobs.len() {
        if fifo.per_job[j].met {
            assert!(edf.per_job[j].met, "EDF missed a deadline FIFO met (job {j})");
            assert!(llf.per_job[j].met, "LLF missed a deadline FIFO met (job {j})");
        }
    }
}

fn run_registry(name: &str) -> Report {
    ExperimentRegistry::with_defaults()
        .run(name, &ExpContext::new())
        .unwrap_or_else(|e| panic!("{name}: {e:#}"))
}

/// `pacpp exp run fleet --format json` acceptance shape: >= 3 policies
/// x >= 2 traces x >= 2 envs, with throughput / p50 / p95 / p99 /
/// utilization columns, and a lossless JSON round-trip.
#[test]
fn fleet_experiment_covers_grid_and_roundtrips_json() {
    let rep = run_registry("fleet");
    let distinct = |col: &str| {
        let mut v: Vec<String> = (0..rep.n_rows())
            .filter_map(|i| rep.cell(i, col).and_then(Cell::as_str).map(String::from))
            .collect();
        v.sort();
        v.dedup();
        v
    };
    assert!(distinct("policy").len() >= 3, "policies: {:?}", distinct("policy"));
    assert!(distinct("trace").len() >= 2, "traces: {:?}", distinct("trace"));
    assert!(distinct("env").len() >= 2, "envs: {:?}", distinct("env"));
    for col in ["throughput", "p50", "p95", "p99", "utilization"] {
        assert!(rep.columns().iter().any(|c| c.name == col), "missing {col}");
    }
    // every cell simulated something: jobs arrived and were accounted for
    for i in 0..rep.n_rows() {
        let completed = rep.cell(i, "completed").unwrap().as_f64().unwrap();
        let failed = rep.cell(i, "failed").unwrap().as_f64().unwrap();
        let jobs = rep.cell(i, "jobs").unwrap().as_f64().unwrap();
        assert!(completed + failed <= jobs, "row {i}");
        assert!(completed > 0.0, "row {i} completed nothing");
    }

    let json = rep.render(Format::Json);
    let back = Report::from_json(&Json::parse(&json).expect("valid json")).expect("report");
    assert_eq!(back, rep, "JSON round-trip must be lossless");
}

/// The churn grid reports churn effects somewhere (replans on the
/// preempt rows, restarts + lost work on the restart-policy rows).
#[test]
fn fleet_churn_experiment_reports_churn_effects() {
    let rep = run_registry("fleet_churn");
    let sum = |col: &str| -> f64 {
        (0..rep.n_rows())
            .filter_map(|i| rep.cell(i, col).and_then(Cell::as_f64))
            .sum()
    };
    assert!(sum("replans") > 0.0);
    assert!(sum("restarts") > 0.0);
    assert!(sum("work_lost") > 0.0);
}
