//! End-to-end PAC+ training of a ~100M-parameter transformer — the full
//! three-layer stack on a real workload:
//!
//! * L1 Pallas flash-attention kernels inside the backbone HLO,
//! * L2 AOT-lowered JAX train steps (`artifacts/base100m`),
//! * L3 this Rust coordinator: worker threads, activation cache,
//!   gradient AllReduce, epoch phases.
//!
//! Epoch 1 runs the frozen backbone forward per micro-batch and fills the
//! activation cache; every later epoch trains the Parallel Adapters
//! *without touching the backbone* — the paper's headline mechanism.
//! The loss curve and per-epoch wall-clock are printed and recorded in
//! EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_e2e
//! # smaller/faster: cargo run --release --example train_e2e -- --artifacts artifacts/small
//! ```

use std::sync::Arc;

use pacpp::data::SyntheticTask;
use pacpp::exec::{self, TrainOptions};
use pacpp::runtime::Runtime;
use pacpp::util::cli::Args;
use pacpp::util::fmt_secs;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).collect());
    let dir = args.get_or("artifacts", "artifacts/base100m");
    let epochs = args.get_usize("epochs", 8);
    let samples = args.get_usize("samples", 256);
    let workers = args.get_usize("workers", 4);

    println!("== PAC+ end-to-end training ==");
    let rt = Arc::new(Runtime::load(dir)?);
    let cfg = rt.manifest.config.clone();
    println!(
        "model {}: {} layers x d={} ({:.1}M backbone params, {:.2}M adapter), B={} S={}",
        cfg.name,
        cfg.layers,
        cfg.d_model,
        cfg.params_backbone as f64 / 1e6,
        cfg.params_adapter as f64 / 1e6,
        cfg.batch,
        cfg.seq_len
    );
    println!("PJRT platform: {}", rt.platform());

    let task = SyntheticTask::generate(samples + 64, cfg.seq_len, cfg.vocab, 0.02, 7);
    let (train, eval) = task.split(64.0 / (samples + 64) as f64);
    println!(
        "dataset: {} train / {} eval samples ({} micro-batches/epoch, {} workers)\n",
        train.len(),
        eval.len(),
        train.len() / cfg.batch,
        workers
    );

    let mut opts = TrainOptions::new(std::env::temp_dir().join("pacpp_e2e_cache"));
    opts.epochs = epochs;
    opts.lr = args.get_f64("lr", 0.005) as f32;
    opts.workers = workers;
    opts.init_tag = "adapter_prune".into();

    let t0 = std::time::Instant::now();
    let log = exec::train_data_parallel(&rt, &train, &opts)?;
    let total = t0.elapsed().as_secs_f64();

    println!("loss curve (per optimizer step):");
    let stride = (log.steps.len() / 40).max(1);
    for s in log.steps.iter().step_by(stride) {
        println!("  epoch {:>2} step {:>4}  loss {:.4}", s.epoch, s.step, s.loss);
    }
    println!("\nper-epoch wall-clock:");
    for (e, t) in log.epoch_times.iter().enumerate() {
        let phase = if e == 0 { "backbone fwd + adapter (cache build)" } else { "cached: adapter only" };
        println!(
            "  epoch {e}: {:<10} mean loss {:.4}   [{phase}]",
            fmt_secs(*t),
            log.mean_loss(e)
        );
    }
    let speedup = log.epoch_times[0] / log.epoch_times[1..].iter().sum::<f64>()
        * (log.epoch_times.len() - 1) as f64;
    println!(
        "\nactivation-cache speedup: epoch1 {} vs cached-epoch mean {} ({:.1}x)",
        fmt_secs(log.epoch_times[0]),
        fmt_secs(log.epoch_times[1..].iter().sum::<f64>() / (epochs - 1).max(1) as f64),
        speedup
    );
    println!(
        "cache hits {} / backbone passes {} (total {})",
        log.cache_hits,
        log.backbone_passes,
        fmt_secs(total)
    );

    let adapter = exec::take_final_adapter().expect("adapter missing");
    let (eloss, acc) = exec::evaluate(&rt, &adapter, &eval, &None)?;
    println!("\nheld-out eval: loss {eloss:.4}, accuracy {:.1}%", acc * 100.0);

    assert!(
        log.mean_loss(epochs - 1) < log.mean_loss(0),
        "training did not reduce the loss: {} -> {}",
        log.mean_loss(0),
        log.mean_loss(epochs - 1)
    );
    println!("\ntrain_e2e OK");
    Ok(())
}
