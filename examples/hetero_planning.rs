//! Heterogeneity-aware planning walkthrough (paper §V-A + Fig. 17).
//!
//! Plans all three evaluation models over the heterogeneous Env.B and a
//! range of homogeneous Nano clusters, showing how the DP planner picks
//! stage boundaries, device groups, and per-device sample dispatch — and
//! what the heterogeneity-unaware ablation (the older PAC planner) loses.
//!
//! ```bash
//! cargo run --release --example hetero_planning
//! ```

use pacpp::cluster::Env;
use pacpp::model::graph::LayerGraph;
use pacpp::model::{Method, ModelSpec, Precision};
use pacpp::planner::{plan, PlannerOptions};
use pacpp::profiler::Profile;
use pacpp::sched::simulate_minibatch;
use pacpp::util::{fmt_bytes, fmt_secs};

fn show_plan(spec: &ModelSpec, env: &Env, hetero: bool) -> Option<f64> {
    let profile =
        Profile::new(LayerGraph::new(spec.clone()), Method::pa(false), Precision::FP32, 128);
    let opts = PlannerOptions {
        microbatch: 8,
        n_microbatches: 4,
        hetero_aware: hetero,
        ..Default::default()
    };
    match plan(&profile, env, &opts) {
        Ok(p) => {
            println!(
                "  {} planner: {} stages {}",
                if hetero { "hetero-aware" } else { "homogeneous" },
                p.n_stages(),
                p.grouping()
            );
            for (i, s) in p.stages.iter().enumerate() {
                let devs: Vec<String> = s
                    .devices
                    .iter()
                    .zip(&s.dispatch)
                    .map(|(d, b)| format!("{}:{}smp", d.kind.name(), b))
                    .collect();
                println!(
                    "    stage {i} blocks [{:>2},{:>2})  [{}]  peak {}",
                    s.range.0,
                    s.range.1,
                    devs.join(" "),
                    fmt_bytes(s.peak_mem)
                );
            }
            let sim = simulate_minibatch(&p, &profile, &env.network);
            println!(
                "    minibatch {} (bubbles {:.0}%)",
                fmt_secs(sim.minibatch_time),
                sim.bubble_fraction * 100.0
            );
            Some(sim.minibatch_time)
        }
        Err(e) => {
            println!("  planning failed: {e}");
            None
        }
    }
}

fn main() {
    println!("== heterogeneity-aware planning (Env.B: TX2-H, TX2-L, Nano-H, Nano-L) ==");
    let env_b = Env::env_b();
    for spec in ModelSpec::paper_models() {
        println!("\n{}:", spec.name);
        let het = show_plan(&spec, &env_b, true);
        let homo = show_plan(&spec, &env_b, false);
        if let (Some(h), Some(o)) = (het, homo) {
            println!(
                "  => heterogeneity awareness saves {:.0}% latency",
                (1.0 - h / o) * 100.0
            );
        }
    }

    println!("\n== grouping evolution over cluster size (Fig. 17) ==");
    for spec in ModelSpec::paper_models() {
        println!("\n{}:", spec.name);
        for n in 2..=8 {
            let env = Env::nanos(n);
            let profile = Profile::new(
                LayerGraph::new(spec.clone()),
                Method::pa(false),
                Precision::FP32,
                128,
            );
            let opts = PlannerOptions {
                microbatch: (n / 2).max(2),
                n_microbatches: 4,
                ..Default::default()
            };
            match plan(&profile, &env, &opts) {
                Ok(p) => println!("  {n} devices: {}", p.grouping()),
                Err(e) => println!("  {n} devices: {e}"),
            }
        }
    }
}
