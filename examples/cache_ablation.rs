//! Activation-cache ablation on the *real* runtime (paper §IV-B, Fig. 18).
//!
//! Trains the `small` model twice over several epochs — once with the
//! PAC+ activation cache and once recomputing the backbone forward every
//! epoch — and verifies (a) identical loss trajectories (the cache is
//! exact, not approximate) and (b) the wall-clock reduction growing with
//! epoch count. Also demonstrates the INT8-quantized backbone variant.
//!
//! ```bash
//! make artifacts && cargo run --release --example cache_ablation
//! ```

use std::sync::Arc;

use pacpp::data::SyntheticTask;
use pacpp::exec::{self, TrainOptions};
use pacpp::runtime::Runtime;
use pacpp::util::cli::Args;
use pacpp::util::fmt_secs;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).collect());
    let dir = args.get_or("artifacts", "artifacts/small");
    let epochs = args.get_usize("epochs", 5);
    let rt = Arc::new(Runtime::load(dir)?);
    let cfg = rt.manifest.config.clone();
    println!("== activation-cache ablation ({}; {} epochs) ==\n", cfg.name, epochs);

    let task = SyntheticTask::generate(160, cfg.seq_len, cfg.vocab, 0.02, 21);

    let mut with = TrainOptions::new(std::env::temp_dir().join("pacpp_abl_cache"));
    with.epochs = epochs;
    with.workers = 2;
    let mut without = with.clone();
    without.cache_dir = std::env::temp_dir().join("pacpp_abl_nocache");
    without.use_cache = false;

    let log_c = exec::train_data_parallel(&rt, &task, &with)?;
    let _ = exec::take_final_adapter();
    let log_n = exec::train_data_parallel(&rt, &task, &without)?;
    let _ = exec::take_final_adapter();

    // (a) exactness: cached activations change nothing about training
    for (a, b) in log_c.steps.iter().zip(&log_n.steps) {
        assert!(
            (a.loss - b.loss).abs() < 1e-4,
            "cache changed the loss trajectory: {} vs {}",
            a.loss,
            b.loss
        );
    }
    println!("loss trajectories identical with/without cache (cache is exact)\n");

    // (b) time: epochs >= 2 skip the backbone forward entirely
    println!("{:<8} {:>14} {:>14} {:>10}", "epoch", "no-cache", "cache", "saving");
    let mut tot_c = 0.0;
    let mut tot_n = 0.0;
    for e in 0..epochs {
        let (tc, tn) = (log_c.epoch_times[e], log_n.epoch_times[e]);
        tot_c += tc;
        tot_n += tn;
        println!(
            "{:<8} {:>14} {:>14} {:>9.0}%",
            e,
            fmt_secs(tn),
            fmt_secs(tc),
            (1.0 - tc / tn) * 100.0
        );
    }
    println!(
        "{:<8} {:>14} {:>14} {:>9.0}%  <= grows with epochs (Fig. 18)",
        "total",
        fmt_secs(tot_n),
        fmt_secs(tot_c),
        (1.0 - tot_c / tot_n) * 100.0
    );
    println!(
        "\nbackbone passes: {} (cache) vs {} (no cache); cache hits {}",
        log_c.backbone_passes, log_n.backbone_passes, log_c.cache_hits
    );

    // (c) the INT8 backbone variant builds the same cache at 1/4 the
    // weight bytes (paper §IV-D)
    if rt.manifest.artifacts.contains_key("qbackbone_fwd_int8") {
        let mut q = with.clone();
        q.cache_dir = std::env::temp_dir().join("pacpp_abl_int8");
        q.quant = Some("int8".into());
        q.epochs = 2;
        let log_q = exec::train_data_parallel(&rt, &task, &q)?;
        let adapter = exec::take_final_adapter().expect("adapter");
        let (l, acc) = exec::evaluate(&rt, &adapter, &task, &q.quant)?;
        println!(
            "\nINT8 backbone: final train loss {:.4}, eval loss {l:.4}, acc {:.1}% \
             (vs FP32 first-epochs loss {:.4})",
            log_q.final_loss(),
            acc * 100.0,
            log_c.mean_loss(1)
        );
    }

    println!("\ncache_ablation OK");
    Ok(())
}
