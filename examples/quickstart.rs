//! Quickstart: plan and simulate PAC+ fine-tuning on the paper's two
//! evaluation environments — no artifacts required.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pacpp::baselines::{run_system, System, TrainJob};
use pacpp::cluster::Env;
use pacpp::data::Task;
use pacpp::model::graph::LayerGraph;
use pacpp::model::{Method, ModelSpec, Precision};
use pacpp::planner::{plan, PlannerOptions};
use pacpp::profiler::Profile;
use pacpp::sched::simulate_minibatch;
use pacpp::util::{fmt_bytes, fmt_secs};

fn main() {
    println!("== PAC+ quickstart ==\n");

    // 1. Describe the model and the fine-tuning method.
    let spec = ModelSpec::t5_large();
    let method = Method::pa(true); // Parallel Adapters + activation cache
    let profile = Profile::new(LayerGraph::new(spec.clone()), method, Precision::FP32, 128);
    println!(
        "model: {} ({:.2}B params, adapter {:.1}M trainable)",
        spec.name,
        spec.params_total() as f64 / 1e9,
        method.trainable_params(&spec) as f64 / 1e6
    );

    // 2. Plan hybrid parallelism on the homogeneous Env.A.
    let env = Env::env_a();
    let opts = PlannerOptions { microbatch: 4, n_microbatches: 4, ..Default::default() };
    let p = plan(&profile, &env, &opts).expect("planning failed");
    println!("\nplan on {} ({} devices):", env.name, env.n());
    println!("  stages: {}  grouping: {}", p.n_stages(), p.grouping());
    for (i, s) in p.stages.iter().enumerate() {
        println!(
            "  stage {i}: blocks [{:>2}, {:>2})  {} device(s), dispatch {:?}, peak mem {}",
            s.range.0,
            s.range.1,
            s.devices.len(),
            s.dispatch,
            fmt_bytes(s.peak_mem)
        );
    }

    // 3. Simulate one mini-batch through the 1F1B pipeline.
    let sim = simulate_minibatch(&p, &profile, &env.network);
    println!(
        "\n1F1B simulation: minibatch {}  (bubbles {:.0}%, in-flight {:?})",
        fmt_secs(sim.minibatch_time),
        sim.bubble_fraction * 100.0,
        sim.peak_in_flight
    );

    // 4. Full fine-tuning run (MRPC, 3 epochs) vs the baselines.
    println!("\nMRPC x 3 epochs on Env.A:");
    let job = TrainJob::new(Task::Mrpc.train_samples(), 3, 128, 16);
    for system in [
        System::PipelineParallel,
        System::DataParallel,
        System::Standalone,
        System::PacPlus,
    ] {
        // baselines use serial Adapters (their best non-OOM method);
        // PAC+ uses Parallel Adapters with the cache
        let m = if system == System::PacPlus { method } else { Method::adapters_default() };
        let prof = Profile::new(LayerGraph::new(spec.clone()), m, Precision::FP32, 128);
        match run_system(system, &prof, &env, job) {
            Ok(r) => println!("  {:<14} {}", system.name(), fmt_secs(r.total)),
            Err(e) => println!("  {:<14} {e}", system.name()),
        }
    }
}
