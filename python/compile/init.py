"""Adapter weight-initialization strategies (paper §IV-C, Fig. 7/14).

Four strategies are compared in the paper's Fig. 14:

* ``gaussian`` — random N(0, 0.02) (the LoRA-style default),
* ``zero``     — zero projection weights (slowest to converge),
* ``prune``    — norm-based structural pruning of the backbone down to the
  adapter width (Torch-Pruning-style: keep the top-``Da`` hidden channels
  and top-``Fa`` FFN channels by aggregate weight norm),
* ``distill``  — short knowledge-distillation loop matching the adapter's
  up-projected output to the backbone's final hidden states on unlabeled
  (random-token) data — the paper runs this "in the cloud"; here it runs
  at artifact-build time.

All return the adapter flat-parameter list of `model.adapter_spec`.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from . import model as M

STRATEGIES = ("gaussian", "zero", "prune", "distill")


def init_adapter(cfg: ModelConfig, strategy: str, backbone=None, seed: int = 1,
                 distill_steps: int = 300, distill_lr: float = 3e-3):
    if strategy == "gaussian":
        return M.init_adapter_gaussian(cfg, seed)
    if strategy == "zero":
        return init_zero(cfg, seed)
    if strategy == "prune":
        assert backbone is not None, "prune init needs backbone params"
        return init_prune(cfg, backbone, seed)
    if strategy == "distill":
        assert backbone is not None, "distill init needs backbone params"
        return init_distill(cfg, backbone, seed, distill_steps, distill_lr)
    raise ValueError(f"unknown init strategy {strategy!r}")


def init_zero(cfg: ModelConfig, seed: int = 1):
    """Zero init for all projections; W_down stays Gaussian (a fully-zero
    adapter passes no signal at all and has exactly-zero gradients)."""
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in M.adapter_spec(cfg):
        short = name.split(".")[-1]
        if short in ("ln1", "ln2"):
            out.append(np.ones(shape, np.float32))
        elif short == "lam":
            out.append(np.full(shape, 0.5, np.float32))
        elif short in ("w_down", "w_down0"):
            out.append(rng.normal(0.0, 0.02, shape).astype(np.float32))
        else:
            out.append(np.zeros(shape, np.float32))
    return out


# ---------------------------------------------------------------------------
# Structural-pruning init
# ---------------------------------------------------------------------------

def _channel_importance(layer):
    """Aggregate L2 norm of each hidden channel across a layer's weights."""
    _, wq, wk, wv, wo, _, w1, w2 = layer
    imp = (np.linalg.norm(wq, axis=1) + np.linalg.norm(wk, axis=1)
           + np.linalg.norm(wv, axis=1) + np.linalg.norm(wo, axis=0)
           + np.linalg.norm(w1, axis=1) + np.linalg.norm(w2, axis=0))
    return imp


def _ffn_importance(layer):
    _, _, _, _, _, _, w1, w2 = layer
    return np.linalg.norm(w1, axis=0) + np.linalg.norm(w2, axis=1)


def _topk_sorted(imp, k):
    idx = np.argpartition(-imp, k - 1)[:k]
    return np.sort(idx)


def _selection_matrix(d, idx):
    s = np.zeros((d, len(idx)), np.float32)
    s[idx, np.arange(len(idx))] = 1.0
    return s


def init_prune(cfg: ModelConfig, backbone, seed: int = 1):
    """Norm-criterion structural pruning of the backbone to adapter width."""
    rng = np.random.default_rng(seed)
    d, da, fa = cfg.d_model, cfg.d_adapter, cfg.d_ff_adapter
    layers = [backbone[2 + i * 8: 2 + (i + 1) * 8] for i in range(cfg.layers)]

    out = []
    idx0 = _topk_sorted(_channel_importance(layers[0]), da)
    out.append(_selection_matrix(d, idx0))  # w_down0

    last_idx = idx0
    for i in range(cfg.layers):
        ln1, wq, wk, wv, wo, ln2, w1, w2 = [np.asarray(a) for a in layers[i]]
        idx = _topk_sorted(_channel_importance(layers[i]), da)
        idxf = _topk_sorted(_ffn_importance(layers[i]), fa)
        out.append(_selection_matrix(d, idx))                    # w_down
        out.append(np.full((1,), 0.5, np.float32))               # lam
        out.append(ln1[idx])                                     # ln1
        out.append(wq[np.ix_(idx, idx)])                         # wq
        out.append(wk[np.ix_(idx, idx)])
        out.append(wv[np.ix_(idx, idx)])
        out.append(wo[np.ix_(idx, idx)])
        out.append(ln2[idx])
        out.append(w1[np.ix_(idx, idxf)])
        out.append(w2[np.ix_(idxf, idx)])
        last_idx = idx

    out.append(_selection_matrix(d, last_idx).T)                 # w_up
    out.append(rng.normal(0.0, 0.02, (d, cfg.n_classes)).astype(np.float32))
    out.append(np.zeros((cfg.n_classes,), np.float32))
    return out


# ---------------------------------------------------------------------------
# Knowledge-distillation init
# ---------------------------------------------------------------------------

def _adapter_hidden(cfg, aparams, acts):
    """Final up-projected adapter hidden states [B, S, D] (pre-head)."""
    a = acts[0] @ aparams[0]
    for i in range(cfg.layers):
        off = 1 + i * M.ARRAYS_PER_ADAPTER_LAYER
        w_down, lam = aparams[off], aparams[off + 1]
        lp = aparams[off + 2:off + 10]
        comb = lam[0] * (acts[i + 1] @ w_down) + (1.0 - lam[0]) * a
        a = M._layer_fwd(comb, lp, cfg.adapter_heads, use_pallas=False)
    return a @ aparams[-3]


def init_distill(cfg: ModelConfig, backbone, seed: int = 1,
                 steps: int = 300, lr: float = 3e-3, batch: int = None):
    """Distill the backbone's final hidden states into the adapter.

    Teacher: frozen backbone (final residual stream b_L). Student: the
    Parallel Adapter stack. Data: random token sequences (the in-repo
    stand-in for the paper's "open dataset in the cloud"). Loss: MSE of
    hidden states. Starts from the prune init (best of both)."""
    rng = np.random.default_rng(seed)
    batch = batch or cfg.batch
    aparams = [jnp.asarray(a) for a in init_prune(cfg, backbone, seed)]
    bparams = [jnp.asarray(a) for a in backbone]

    @jax.jit
    def step(ap, tokens):
        acts = jax.lax.stop_gradient(
            M.backbone_fwd(cfg, bparams, tokens, use_pallas=False))

        def loss_fn(ap_):
            h = _adapter_hidden(cfg, ap_, acts)
            return jnp.mean(jnp.square(h - acts[-1]))

        loss, grads = jax.value_and_grad(loss_fn)(ap)
        return [p - lr * g for p, g in zip(ap, grads)], loss

    loss = None
    for _ in range(steps):
        tokens = rng.integers(0, cfg.vocab, (batch, cfg.seq_len)).astype(np.int32)
        aparams, loss = step(aparams, tokens)
    return [np.asarray(a) for a in aparams]
