"""Model configurations for the PAC+ reproduction.

Two kinds of configs live here:

* **Runnable configs** (`tiny`, `small`, `base100m`) — encoder transformers
  that are actually AOT-lowered to HLO artifacts and executed by the Rust
  runtime. `base100m` is the ~100M-parameter model used by the end-to-end
  example (`examples/train_e2e.rs`).
* **Paper configs** (`t5-base`, `t5-large`, `bart-large`) — layer-count /
  width descriptors of the paper's evaluation models (Table III). These are
  consumed by the Rust analytic cost model to regenerate Fig. 3 / Table I /
  Table V etc.; they are far too large to execute on this CPU testbed.

The paper's models are encoder-decoder (en-de); the runnable path here uses
an encoder + pooled classification head, which exercises the identical
system machinery (per-layer activations, adapters, cache, pipeline stages).
The substitution is recorded in DESIGN.md §2.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    """A transformer backbone + Parallel Adapters configuration."""

    name: str
    layers: int            # L — number of transformer layers
    d_model: int           # d — hidden size
    n_heads: int           # attention heads
    d_ff: int              # feed-forward inner size
    vocab: int             # vocabulary size
    seq_len: int           # fixed sequence length (static AOT shapes)
    batch: int             # per-device micro-batch used for lowering
    reduction: int = 8     # r — adapter width reduction factor (paper: 8)
    n_classes: int = 2     # classification head width
    runnable: bool = True  # False => cost-model-only descriptor

    @property
    def d_adapter(self) -> int:
        """Adapter hidden width d/r (paper §IV-A)."""
        assert self.d_model % self.reduction == 0
        return self.d_model // self.reduction

    @property
    def d_ff_adapter(self) -> int:
        return max(4, self.d_ff // self.reduction)

    @property
    def adapter_heads(self) -> int:
        """Head count for the adapter's attention, adjusted to divide d/r."""
        h = max(1, self.n_heads // self.reduction)
        da = self.d_adapter
        while da % h != 0:
            h -= 1
        return h

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count_backbone(self) -> int:
        """Parameter count of the frozen backbone (embeddings included)."""
        per_layer = (
            2 * self.d_model                      # rmsnorm scales
            + 4 * self.d_model * self.d_model     # Wq Wk Wv Wo
            + 2 * self.d_model * self.d_ff        # W1 W2
        )
        return (
            self.vocab * self.d_model             # token embedding
            + self.seq_len * self.d_model         # positional embedding
            + self.layers * per_layer
            + self.d_model                        # final norm
        )

    def param_count_adapter(self) -> int:
        da, dff = self.d_adapter, self.d_ff_adapter
        per_layer = 2 * da + 4 * da * da + 2 * da * dff
        return (
            (self.layers + 1) * self.d_model * da     # W_down_0..L
            + self.layers                             # lambda_i
            + self.layers * per_layer
            + da * self.d_model                       # W_up
            + self.d_model * self.n_classes + self.n_classes  # head
        )

    def to_dict(self) -> dict:
        d = asdict(self)
        d["d_adapter"] = self.d_adapter
        d["d_ff_adapter"] = self.d_ff_adapter
        d["adapter_heads"] = self.adapter_heads
        d["params_backbone"] = self.param_count_backbone()
        d["params_adapter"] = self.param_count_adapter()
        return d


# --------------------------------------------------------------------------
# Runnable configurations
# --------------------------------------------------------------------------

TINY = ModelConfig(
    name="tiny", layers=2, d_model=32, n_heads=2, d_ff=64,
    vocab=128, seq_len=16, batch=4, reduction=4, n_classes=2,
)

SMALL = ModelConfig(
    name="small", layers=4, d_model=128, n_heads=4, d_ff=256,
    vocab=1000, seq_len=32, batch=8, reduction=8, n_classes=2,
)

# ~97M backbone parameters: the end-to-end example's model.
BASE100M = ModelConfig(
    name="base100m", layers=12, d_model=768, n_heads=12, d_ff=3072,
    vocab=16000, seq_len=64, batch=8, reduction=8, n_classes=2,
)

# --------------------------------------------------------------------------
# Paper model descriptors (Table III) — cost model only, never lowered.
# --------------------------------------------------------------------------

T5_BASE = ModelConfig(
    name="t5-base", layers=12, d_model=768, n_heads=12, d_ff=3072,
    vocab=32128, seq_len=128, batch=16, reduction=8, runnable=False,
)
BART_LARGE = ModelConfig(
    name="bart-large", layers=12, d_model=1024, n_heads=16, d_ff=4096,
    vocab=50265, seq_len=128, batch=16, reduction=8, runnable=False,
)
T5_LARGE = ModelConfig(
    name="t5-large", layers=24, d_model=1024, n_heads=16, d_ff=4096,
    vocab=32128, seq_len=128, batch=16, reduction=8, runnable=False,
)

CONFIGS = {c.name: c for c in [TINY, SMALL, BASE100M, T5_BASE, BART_LARGE, T5_LARGE]}


def get_config(name: str) -> ModelConfig:
    try:
        return CONFIGS[name]
    except KeyError:
        raise KeyError(f"unknown config {name!r}; available: {sorted(CONFIGS)}")
