"""Block-wise absmax quantization (paper §IV-D, Eq. 1–2).

The backbone LLM weights are stored in a low-bit integer format and
dequantized to the compute dtype (f32) on the fly. Following QLoRA-style
block-wise quantization, each weight matrix ``W in R^{K x N}`` is split
into blocks of ``BLOCK`` consecutive entries along K (per output column n),
and each block gets its own absmax scale. This bounds the blast radius of
outliers (paper §IV-D).

Storage layout used across the whole repo (Python oracle, Pallas kernel,
and the Rust `quant` module all agree on it):

    w_q    : int8 [K, N]          quantized values in [-Q, Q]
    scales : f32  [ceil(K/B), N]  absmax of each (block, column)

with Q = 127 for INT8 and Q = 7 for INT4 (INT4 values are stored one per
int8 byte; the 2x packing is a pure storage concern handled by the Rust
side's bit-packing tests, not by the compute path).
"""

import numpy as np
import jax.numpy as jnp

BLOCK = 64

QMAX = {"int8": 127, "int4": 7}


def _check(w):
    if w.ndim != 2:
        raise ValueError(f"expected 2-D weight, got shape {w.shape}")


def quantize_blockwise(w: np.ndarray, bits: str = "int8", block: int = BLOCK):
    """Quantize a [K, N] f32 matrix block-wise along K. Returns (w_q, scales).

    Implements Eq. (1): X_q = round(Q / absmax(X_block) * X_block).
    Blocks where absmax == 0 get scale 1.0 (their values are all zeros).
    """
    _check(w)
    qmax = QMAX[bits]
    k, n = w.shape
    nblocks = -(-k // block)  # ceil
    pad = nblocks * block - k
    wp = np.pad(w.astype(np.float32), ((0, pad), (0, 0)))
    wb = wp.reshape(nblocks, block, n)
    absmax = np.abs(wb).max(axis=1)  # [nblocks, n]
    scales = np.where(absmax == 0.0, 1.0, absmax).astype(np.float32)
    q = np.rint(wb * (qmax / scales[:, None, :]))
    q = np.clip(q, -qmax, qmax).astype(np.int8)
    return q.reshape(nblocks * block, n)[:k], scales


def dequantize_blockwise(w_q: np.ndarray, scales: np.ndarray,
                         bits: str = "int8", block: int = BLOCK) -> np.ndarray:
    """Inverse of :func:`quantize_blockwise` (Eq. 2)."""
    _check(w_q)
    qmax = QMAX[bits]
    k, n = w_q.shape
    nblocks = scales.shape[0]
    pad = nblocks * block - k
    qp = np.pad(w_q.astype(np.float32), ((0, pad), (0, 0)))
    qb = qp.reshape(nblocks, block, n)
    wb = qb * (scales[:, None, :] / qmax)
    return wb.reshape(nblocks * block, n)[:k].astype(np.float32)


def dequantize_blockwise_jnp(w_q, scales, bits: str = "int8", block: int = BLOCK):
    """jnp version usable inside jitted/lowered graphs."""
    qmax = QMAX[bits]
    k, n = w_q.shape
    nblocks = scales.shape[0]
    pad = nblocks * block - k
    qp = jnp.pad(w_q.astype(jnp.float32), ((0, pad), (0, 0)))
    qb = qp.reshape(nblocks, block, n)
    wb = qb * (scales[:, None, :] / qmax)
    return wb.reshape(nblocks * block, n)[:k]


def quantization_error(w: np.ndarray, bits: str = "int8", block: int = BLOCK) -> float:
    """Max elementwise round-trip error, normalized by per-block absmax."""
    q, s = quantize_blockwise(w, bits, block)
    w2 = dequantize_blockwise(q, s, bits, block)
    denom = max(np.abs(w).max(), 1e-12)
    return float(np.abs(w - w2).max() / denom)


def quantized_bytes(shape, bits: str = "int8", block: int = BLOCK) -> int:
    """Storage bytes of a quantized [K, N] weight (values + scales).

    INT4 counts 0.5 byte/value (packed); the scales are f32.
    """
    k, n = shape
    nblocks = -(-k // block)
    val_bytes = k * n if bits == "int8" else (k * n + 1) // 2
    return val_bytes + nblocks * n * 4
