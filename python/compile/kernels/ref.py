"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` function is the semantic specification that the Pallas
kernel of the same name must reproduce (``pytest python/tests`` asserts
allclose across a hypothesis-driven shape/dtype sweep). These are also the
implementations used inside differentiated subgraphs, where Pallas
(interpret-mode, no custom VJP) cannot be used.
"""

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, scale=None):
    """Multi-head scaled dot-product attention (no mask — encoder style).

    q, k, v: [B, H, S, Dh]  ->  [B, H, S, Dh]
    """
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k) * scale
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs, v)


def block_dequant_matmul_ref(x, w_q, scales, qmax=127, block=64):
    """x @ dequant(w_q, scales) with block-wise absmax dequantization.

    x: [M, K] f32; w_q: [K, N] int8; scales: [ceil(K/B), N] f32.
    Matches quantize.dequantize_blockwise_jnp followed by a matmul.
    """
    k, n = w_q.shape
    nblocks = scales.shape[0]
    pad = nblocks * block - k
    qp = jnp.pad(w_q.astype(jnp.float32), ((0, pad), (0, 0)))
    qb = qp.reshape(nblocks, block, n)
    w = (qb * (scales[:, None, :] / qmax)).reshape(nblocks * block, n)[:k]
    return x @ w


def adapter_combine_ref(b, a, w_down, lam):
    """Fused adapter input combination (paper §IV-A, Fig. 6).

    input_i = lambda_i * (b_i @ W_down_i) + (1 - lambda_i) * a_{i-1}

    b: [S, D] backbone activation; a: [S, Da] adapter state;
    w_down: [D, Da]; lam: scalar in [0, 1].
    """
    return lam * (b @ w_down) + (1.0 - lam) * a


def rmsnorm_ref(x, scale, eps=1e-6):
    """RMSNorm: x * scale / rms(x). x: [..., D], scale: [D]."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def ffn_ref(x, w1, w2):
    """Transformer feed-forward: gelu(x @ w1) @ w2."""
    return jax.nn.gelu(x @ w1) @ w2
