"""Pallas kernel: block-wise dequantizing matmul (the PAC+ L1 hot-spot).

The frozen, quantized backbone spends ~98% of its FLOPs in GEMMs whose
weights are stored INT8/INT4 block-wise (quantize.py). On the paper's CUDA
testbed this is a per-warp shared-memory dequant; the TPU adaptation
(DESIGN.md §4) streams quantized weight tiles HBM→VMEM at 1/4–1/8 the f32
bytes and dequantizes on the VMEM-resident tile right before feeding the
MXU:

  grid = (M/bm, N/bn, K/bk)  with bk == the quantization block size, so
  each kernel instance consumes exactly one scale row.

Executed with ``interpret=True`` (CPU correctness path); real-TPU numbers
are estimated in DESIGN.md §8.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, s_ref, o_ref, *, qmax):
    """One (bm, bn) output tile, accumulating over the K grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Dequantize the VMEM-resident weight tile: w = w_q * scale / qmax.
    w = w_ref[...].astype(jnp.float32) * (s_ref[0, :] / qmax)
    o_ref[...] += jnp.dot(
        x_ref[...], w, preferred_element_type=jnp.float32
    )


def _pick_tile(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is <= target (tiles must divide)."""
    t = min(dim, target)
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("qmax", "block", "bm", "bn"))
def block_dequant_matmul(x, w_q, scales, qmax: int = 127, block: int = 64,
                         bm: int = 128, bn: int = 128):
    """Compute ``x @ dequant(w_q, scales)``.

    x: [M, K] f32; w_q: [K, N] int8 (values in [-qmax, qmax]);
    scales: [K/block, N] f32 per-(block, column) absmax.
    K must be a multiple of `block`.
    """
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2, (x.shape, w_q.shape)
    assert k % block == 0, f"K={k} not a multiple of quant block {block}"
    assert scales.shape == (k // block, n), (scales.shape, (k // block, n))

    bm = _pick_tile(m, bm)
    bn = _pick_tile(n, bn)
    bk = block  # one scale row per K-tile
    grid = (m // bm, n // bn, k // bk)

    return pl.pallas_call(
        functools.partial(_kernel, qmax=qmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w_q, scales)


def vmem_bytes(bm: int, bn: int, bk: int, bits: str = "int8") -> int:
    """Estimated VMEM working set of one kernel instance (DESIGN.md §8)."""
    x_tile = bm * bk * 4
    w_tile = bm and bk * bn * (1 if bits == "int8" else 1)  # int4 stored unpacked
    s_tile = bn * 4
    o_tile = bm * bn * 4
    return x_tile + w_tile + s_tile + o_tile
