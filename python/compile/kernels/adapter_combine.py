"""Pallas kernel: fused adapter-input combination (paper §IV-A, Fig. 6).

    input_i = lambda_i * (b_i @ W_down_i) + (1 - lambda_i) * a_{i-1}

Fusing the down-projection with the blend means the full-width backbone
activation b_i [S, D] is read from HBM exactly once and the intermediate
(b @ W_down) [S, D/r] never round-trips to HBM — on TPU the tile lives in
VMEM between the MXU matmul and the VPU blend (DESIGN.md §4).

Used on the cache-build / serving path; the differentiated training path
uses the jnp oracle (ref.adapter_combine_ref), which XLA fuses similarly.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(b_ref, a_ref, w_ref, lam_ref, o_ref):
    lam = lam_ref[0, 0]
    proj = jnp.dot(b_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = lam * proj + (1.0 - lam) * a_ref[...]


def _pick_tile(dim: int, target: int) -> int:
    t = min(dim, target)
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("bs", "bda"))
def adapter_combine(b, a, w_down, lam, bs: int = 128, bda: int = 128):
    """Fused ``lam * (b @ w_down) + (1 - lam) * a``.

    b: [S, D] f32; a: [S, Da] f32; w_down: [D, Da] f32; lam: scalar f32.
    """
    s, d = b.shape
    d2, da = w_down.shape
    assert d == d2 and a.shape == (s, da), (b.shape, a.shape, w_down.shape)

    bs = _pick_tile(s, bs)
    bda = _pick_tile(da, bda)
    lam_arr = jnp.asarray(lam, jnp.float32).reshape(1, 1)

    return pl.pallas_call(
        _kernel,
        grid=(s // bs, da // bda),
        in_specs=[
            pl.BlockSpec((bs, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bs, bda), lambda i, j: (i, j)),
            pl.BlockSpec((d, bda), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bs, bda), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, da), jnp.float32),
        interpret=True,
    )(b, a, w_down, lam_arr)
