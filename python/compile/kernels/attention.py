"""Pallas kernel: flash-style multi-head attention for the frozen backbone.

Used only on the non-differentiated backbone forward path (the Parallel
Adapters design means gradients never cross the backbone — paper §IV-A),
so no custom VJP is needed.

TPU adaptation (DESIGN.md §4): each kernel instance owns one (batch*head,
q-block) tile resident in VMEM and streams K/V in chunks with an online
(running max / running denominator) softmax — the standard flash recurrence
— instead of materializing the [S, S] score matrix in HBM the way the
paper's Jetson (CUDA) implementation does with shared-memory tiles.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, o_ref, *, kv_chunk, scale):
    q = q_ref[0]                      # [bq, dh]
    seq = k_ref.shape[1]
    n_chunks = seq // kv_chunk
    bq, dh = q.shape

    def body(c, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[0, pl.dslice(c * kv_chunk, kv_chunk), :]   # [ck, dh]
        v = v_ref[0, pl.dslice(c * kv_chunk, kv_chunk), :]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        m_cur = jnp.max(s, axis=-1)                          # [bq]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])                      # [bq, ck]
        alpha = jnp.exp(m_prev - m_new)                      # [bq]
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc

    init = (
        jnp.full((bq,), -jnp.inf, jnp.float32),
        jnp.zeros((bq,), jnp.float32),
        jnp.zeros((bq, dh), jnp.float32),
    )
    _, l, acc = jax.lax.fori_loop(0, n_chunks, body, init)
    o_ref[0] = acc / l[:, None]


def _pick_tile(dim: int, target: int) -> int:
    t = min(dim, target)
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("bq", "kv_chunk"))
def flash_attention(q, k, v, bq: int = 128, kv_chunk: int = 128):
    """Scaled dot-product attention, [B, H, S, Dh] -> [B, H, S, Dh]."""
    b, h, s, dh = q.shape
    assert k.shape == v.shape == (b, h, s, dh)
    scale = 1.0 / (dh ** 0.5)

    bq = _pick_tile(s, bq)
    kv_chunk = _pick_tile(s, kv_chunk)

    qf = q.reshape(b * h, s, dh)
    kf = k.reshape(b * h, s, dh)
    vf = v.reshape(b * h, s, dh)

    out = pl.pallas_call(
        functools.partial(_kernel, kv_chunk=kv_chunk, scale=scale),
        grid=(b * h, s // bq),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, s, dh), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda g, i: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda g, i: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dh), jnp.float32),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, s, dh)
