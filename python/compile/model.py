"""L2: the PAC+ JAX model — frozen transformer backbone + Parallel Adapters.

Everything here is build-time Python: `aot.py` lowers the functions below
to HLO text artifacts which the Rust runtime loads and executes. Python is
never on the training hot path.

Parameter layout convention (shared with the Rust side via the manifest):
parameters are **flat lists** of arrays in a fixed documented order — no
pytree-dict ordering ambiguity crosses the language boundary.

Backbone (frozen), pre-RMSNorm encoder:
    [tok_emb (V,D), pos_emb (S,D)]
    + per layer i in 0..L:
        [ln1 (D,), wq (D,D), wk (D,D), wv (D,D), wo (D,D),
         ln2 (D,), w1 (D,F), w2 (F,D)]
    + [ln_f (D,)]

Parallel Adapter (trainable), paper §IV-A / Fig. 6:
    [w_down0 (D,Da)]
    + per layer i in 0..L:
        [w_down (D,Da), lam (1,),
         ln1 (Da,), wq (Da,Da), wk, wv, wo, ln2 (Da,), w1 (Da,Fa), w2 (Fa,Da)]
    + [w_up (Da,D), head_w (D,C), head_b (C,)]

The backbone forward returns the stacked per-layer activations
b_0..b_L — exactly the tensors the PAC+ activation cache stores (paper
§IV-B); the adapter consumes only this stack, so `adapter_*` functions are
the phase-2 (cached) training path.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels.ref import attention_ref, rmsnorm_ref, ffn_ref
from .kernels.attention import flash_attention
from .kernels.quant_matmul import block_dequant_matmul
from . import quantize

ARRAYS_PER_BACKBONE_LAYER = 8
ARRAYS_PER_ADAPTER_LAYER = 10


# ---------------------------------------------------------------------------
# Parameter specs + initialization
# ---------------------------------------------------------------------------

def backbone_spec(cfg: ModelConfig):
    """[(name, shape)] for the backbone flat parameter list."""
    d, f = cfg.d_model, cfg.d_ff
    spec = [("tok_emb", (cfg.vocab, d)), ("pos_emb", (cfg.seq_len, d))]
    for i in range(cfg.layers):
        spec += [
            (f"l{i}.ln1", (d,)),
            (f"l{i}.wq", (d, d)), (f"l{i}.wk", (d, d)),
            (f"l{i}.wv", (d, d)), (f"l{i}.wo", (d, d)),
            (f"l{i}.ln2", (d,)),
            (f"l{i}.w1", (d, f)), (f"l{i}.w2", (f, d)),
        ]
    spec.append(("ln_f", (d,)))
    return spec


def adapter_spec(cfg: ModelConfig):
    """[(name, shape)] for the adapter flat parameter list."""
    d, da, fa = cfg.d_model, cfg.d_adapter, cfg.d_ff_adapter
    spec = [("w_down0", (d, da))]
    for i in range(cfg.layers):
        spec += [
            (f"a{i}.w_down", (d, da)), (f"a{i}.lam", (1,)),
            (f"a{i}.ln1", (da,)),
            (f"a{i}.wq", (da, da)), (f"a{i}.wk", (da, da)),
            (f"a{i}.wv", (da, da)), (f"a{i}.wo", (da, da)),
            (f"a{i}.ln2", (da,)),
            (f"a{i}.w1", (da, fa)), (f"a{i}.w2", (fa, da)),
        ]
    spec += [("w_up", (da, d)), ("head_w", (d, cfg.n_classes)),
             ("head_b", (cfg.n_classes,))]
    return spec


def _init_from_spec(spec, rng, scale=0.02):
    out = []
    for name, shape in spec:
        if name.endswith(("ln1", "ln2")) or name == "ln_f":
            out.append(np.ones(shape, np.float32))
        elif name.endswith("lam"):
            out.append(np.full(shape, 0.5, np.float32))  # paper: lam init 0.5
        elif name.endswith("head_b"):
            out.append(np.zeros(shape, np.float32))
        else:
            out.append(rng.normal(0.0, scale, shape).astype(np.float32))
    return out


def init_backbone(cfg: ModelConfig, seed: int = 0):
    rng = np.random.default_rng(seed)
    return _init_from_spec(backbone_spec(cfg), rng)


def init_adapter_gaussian(cfg: ModelConfig, seed: int = 1):
    rng = np.random.default_rng(seed)
    return _init_from_spec(adapter_spec(cfg), rng)


# ---------------------------------------------------------------------------
# Backbone forward
# ---------------------------------------------------------------------------

def _mha(x, wq, wk, wv, wo, n_heads, use_pallas):
    """Multi-head attention block. x: [B, S, D]."""
    b, s, d = x.shape
    dh = d // n_heads

    def split(t):
        return t.reshape(b, s, n_heads, dh).transpose(0, 2, 1, 3)

    q, k, v = split(x @ wq), split(x @ wk), split(x @ wv)
    if use_pallas:
        o = flash_attention(q, k, v)
    else:
        o = attention_ref(q, k, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
    return o @ wo


def _layer_fwd(x, lp, n_heads, use_pallas):
    """One pre-norm transformer layer. lp: the 8 layer arrays."""
    ln1, wq, wk, wv, wo, ln2, w1, w2 = lp
    h = x + _mha(rmsnorm_ref(x, ln1), wq, wk, wv, wo, n_heads, use_pallas)
    return h + ffn_ref(rmsnorm_ref(h, ln2), w1, w2)


def embed_fwd(cfg: ModelConfig, tok_emb, pos_emb, tokens):
    """tokens [B, S] int32 -> b_0 [B, S, D]."""
    return tok_emb[tokens] + pos_emb[None, :, :]


def backbone_layers_fwd(cfg: ModelConfig, layer_params, x, use_pallas=True):
    """Run a span of layers; returns activations after each layer.

    layer_params: flat list, 8 arrays per layer.
    Returns (x_out [B,S,D], acts [K, B, S, D]) where K = #layers in span.
    """
    n = len(layer_params) // ARRAYS_PER_BACKBONE_LAYER
    acts = []
    for i in range(n):
        lp = layer_params[i * 8:(i + 1) * 8]
        x = _layer_fwd(x, lp, cfg.n_heads, use_pallas)
        acts.append(x)
    return x, jnp.stack(acts)


def backbone_fwd(cfg: ModelConfig, params, tokens, use_pallas=True):
    """Full frozen-backbone forward.

    Returns the activation stack b_0..b_L: [L+1, B, S, D] — exactly what
    the PAC+ activation cache stores per input sequence (paper §IV-B).
    The final RMSNorm (ln_f) is applied *inside the adapter head path*,
    not here, so b_L is the raw residual-stream output.
    """
    tok_emb, pos_emb = params[0], params[1]
    layer_params = params[2:2 + cfg.layers * 8]
    b0 = embed_fwd(cfg, tok_emb, pos_emb, tokens)
    _, acts = backbone_layers_fwd(cfg, layer_params, b0, use_pallas)
    return jnp.concatenate([b0[None], acts], axis=0)


# ---------------------------------------------------------------------------
# Quantized backbone forward (paper §IV-D): INT8/INT4 storage, f32 compute.
# ---------------------------------------------------------------------------

QUANTIZED_NAMES = ("wq", "wk", "wv", "wo", "w1", "w2")


def quantize_backbone(cfg: ModelConfig, params, bits="int8", block=None):
    """Quantize every 2-D projection weight of the backbone block-wise.

    Embeddings and norm scales stay f32 (they are a small fraction of
    bytes and quantizing the embedding table hurts accuracy most).
    Returns a flat list where each quantized weight contributes two
    entries: (w_q int8, scales f32); plus the same spec description.
    """
    if block is None:
        block = min(64, cfg.d_model)
    spec = backbone_spec(cfg)
    out, out_spec = [], []
    for (name, shape), w in zip(spec, params):
        short = name.split(".")[-1]
        if short in QUANTIZED_NAMES:
            w_q, scales = quantize_blockwise_np(np.asarray(w), bits, block)
            out += [w_q, scales]
            out_spec += [(name + ".q", w_q.shape, "i8"),
                         (name + ".s", scales.shape, "f32")]
        else:
            out.append(np.asarray(w, np.float32))
            out_spec.append((name, shape, "f32"))
    return out, out_spec


def quantize_blockwise_np(w, bits, block):
    return quantize.quantize_blockwise(w, bits=bits, block=block)


def fp16_backbone(params):
    """Cast every backbone array to f16 storage (paper Table VII's FP16
    row). Compute stays f32: the forward casts back on entry."""
    return [np.asarray(p, np.float16) for p in params]


def fp16_backbone_fwd(cfg: ModelConfig, params_f16, tokens, use_pallas=True):
    """Backbone forward over f16-stored parameters (f32 compute)."""
    params = [jnp.asarray(p, jnp.float32) for p in params_f16]
    return backbone_fwd(cfg, params, tokens, use_pallas)


def quant_backbone_fwd(cfg: ModelConfig, qparams, tokens, bits="int8",
                       block=None, use_pallas=True):
    """Backbone forward over the quantized parameter list.

    Every projection matmul runs through the Pallas block-dequant GEMM
    (the L1 hot-spot); norms/residuals stay f32.
    """
    if block is None:
        block = min(64, cfg.d_model)
    qmax = quantize.QMAX[bits]

    # Walk the quantized flat list back into per-layer structure.
    idx = 0

    def take_f32():
        nonlocal idx
        v = qparams[idx]
        idx += 1
        return v

    def take_q():
        nonlocal idx
        w_q, scales = qparams[idx], qparams[idx + 1]
        idx += 2
        return w_q, scales

    tok_emb = take_f32()
    pos_emb = take_f32()

    def qmm(x2d, wq_s):
        w_q, scales = wq_s
        if use_pallas:
            return block_dequant_matmul(x2d, w_q, scales, qmax=qmax, block=block)
        w = quantize.dequantize_blockwise_jnp(w_q, scales, bits, block)
        return x2d @ w

    x = embed_fwd(cfg, tok_emb, pos_emb, tokens)
    b, s, d = x.shape
    acts = [x]
    for _ in range(cfg.layers):
        ln1 = take_f32()
        wq_, wk_, wv_, wo_ = take_q(), take_q(), take_q(), take_q()
        ln2 = take_f32()
        w1_, w2_ = take_q(), take_q()

        xn = rmsnorm_ref(x, ln1).reshape(b * s, d)
        dh = d // cfg.n_heads

        def split(t2d):
            return t2d.reshape(b, s, cfg.n_heads, dh).transpose(0, 2, 1, 3)

        q_, k_, v_ = split(qmm(xn, wq_)), split(qmm(xn, wk_)), split(qmm(xn, wv_))
        if use_pallas:
            o = flash_attention(q_, k_, v_)
        else:
            o = attention_ref(q_, k_, v_)
        o2d = o.transpose(0, 2, 1, 3).reshape(b * s, d)
        x = x + qmm(o2d, wo_).reshape(b, s, d)

        hn = rmsnorm_ref(x, ln2).reshape(b * s, d)
        inner = jax.nn.gelu(qmm(hn, w1_))
        x = x + qmm(inner, w2_).reshape(b, s, d)
        acts.append(x)

    take_f32()  # ln_f (unused here; applied by the adapter head path)
    return jnp.stack(acts)


# ---------------------------------------------------------------------------
# Parallel Adapter forward / loss / train step (the trainable side network)
# ---------------------------------------------------------------------------

def adapter_fwd(cfg: ModelConfig, aparams, acts):
    """Parallel Adapter forward over cached backbone activations.

    acts: [L+1, B, S, D] (b_0..b_L). Returns logits [B, C].

    a_0   = b_0 @ w_down0
    in_i  = lam_i * (b_{i+1} @ w_down_i) + (1 - lam_i) * a_i
    a_{i+1} = AdapterLayer_i(in_i)
    out   = mean_S(a_L @ w_up) @ head_w + head_b
    """
    da = cfg.d_adapter
    w_down0 = aparams[0]
    a = acts[0] @ w_down0
    for i in range(cfg.layers):
        off = 1 + i * ARRAYS_PER_ADAPTER_LAYER
        w_down, lam = aparams[off], aparams[off + 1]
        lp = aparams[off + 2:off + 10]
        comb = lam[0] * (acts[i + 1] @ w_down) + (1.0 - lam[0]) * a
        a = _layer_fwd(comb, lp, cfg.adapter_heads, use_pallas=False)
    w_up, head_w, head_b = aparams[-3], aparams[-2], aparams[-1]
    up = a @ w_up                                   # [B, S, D]
    pooled = jnp.mean(up, axis=1)                   # [B, D]
    return pooled @ head_w + head_b


def softmax_xent(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def adapter_loss(cfg: ModelConfig, aparams, acts, labels):
    return softmax_xent(adapter_fwd(cfg, aparams, acts), labels)


def sgd(params, grads, lr):
    return [p - lr * g for p, g in zip(params, grads)]


def adapter_step(cfg: ModelConfig, aparams, acts, labels, lr):
    """One SGD step of the adapter on cached activations (phase-2 path).

    Returns (new_params..., loss). This is the artifact executed in a
    data-parallel loop by the Rust coordinator after epoch 1.
    """
    acts = jax.lax.stop_gradient(acts)
    loss, grads = jax.value_and_grad(
        lambda ap: adapter_loss(cfg, ap, acts, labels))(aparams)
    return tuple(sgd(aparams, grads, lr)) + (loss,)


def adapter_grads(cfg: ModelConfig, aparams, acts, labels):
    """Per-microbatch adapter gradients (for cross-device AllReduce).

    Returns (grads..., loss) — the Rust coordinator averages gradients
    across the data-parallel group and applies the update itself.
    """
    acts = jax.lax.stop_gradient(acts)
    loss, grads = jax.value_and_grad(
        lambda ap: adapter_loss(cfg, ap, acts, labels))(aparams)
    return tuple(grads) + (loss,)


def full_step(cfg: ModelConfig, bparams, aparams, tokens, labels, lr,
              use_pallas=True):
    """Epoch-1 step: frozen backbone forward + adapter fwd/bwd.

    Returns (new_adapter_params..., loss, acts). `acts` is handed to the
    Rust activation cache. Gradients never cross the backbone: the
    activation stack is stop_gradient'ed (the paper's "gradient highway").
    """
    acts = jax.lax.stop_gradient(backbone_fwd(cfg, bparams, tokens, use_pallas))
    loss, grads = jax.value_and_grad(
        lambda ap: adapter_loss(cfg, ap, acts, labels))(aparams)
    return tuple(sgd(aparams, grads, lr)) + (loss, acts)


def adapter_eval(cfg: ModelConfig, aparams, acts, labels):
    """Eval pass: (loss, #correct) over one cached batch."""
    logits = adapter_fwd(cfg, aparams, acts)
    loss = softmax_xent(logits, labels)
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.int32))
    return loss, correct


# ---------------------------------------------------------------------------
# Baseline fine-tuning algorithms (accuracy-shape experiments, Table VI /
# Fig. 14 / Table VII). These differentiate *through* the backbone, so they
# use the jnp reference path (no Pallas on differentiated subgraphs).
# ---------------------------------------------------------------------------

def _backbone_logits(cfg, bparams, head, tokens, extra=None):
    """Backbone + pooled classification head; `extra` hooks PEFT variants."""
    acts = backbone_fwd(cfg, bparams, tokens, use_pallas=False)
    x = rmsnorm_ref(acts[-1], bparams[-1])
    pooled = jnp.mean(x, axis=1)
    head_w, head_b = head
    return pooled @ head_w + head_b


def full_ft_step(cfg: ModelConfig, bparams, head, tokens, labels, lr):
    """Full-model fine-tuning baseline: every backbone param is trainable."""
    def loss_fn(bp, hd):
        return softmax_xent(_backbone_logits(cfg, bp, hd, tokens), labels)

    loss, (gb, gh) = jax.value_and_grad(loss_fn, argnums=(0, 1))(bparams, head)
    return tuple(sgd(bparams, gb, lr)) + tuple(sgd(head, gh, lr)) + (loss,)


def lora_spec(cfg: ModelConfig, rank: int = 8):
    """LoRA on Wq and Wv of every layer (paper's setting from [11])."""
    d = cfg.d_model
    spec = []
    for i in range(cfg.layers):
        for nm in ("wq", "wv"):
            spec += [(f"l{i}.{nm}.lora_a", (d, rank)),
                     (f"l{i}.{nm}.lora_b", (rank, d))]
    spec += [("head_w", (d, cfg.n_classes)), ("head_b", (cfg.n_classes,))]
    return spec


def init_lora(cfg: ModelConfig, rank: int = 8, seed: int = 2):
    """A ~ N(0, 0.02), B = 0 so that dW = BA = 0 at init (paper §IV-C)."""
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in lora_spec(cfg, rank):
        if name.endswith("lora_b") or name.endswith("head_b"):
            out.append(np.zeros(shape, np.float32))
        else:
            out.append(rng.normal(0.0, 0.02, shape).astype(np.float32))
    return out


def _lora_backbone_fwd(cfg, bparams, lparams, tokens):
    """Backbone forward with LoRA deltas injected on Wq/Wv."""
    tok_emb, pos_emb = bparams[0], bparams[1]
    x = embed_fwd(cfg, tok_emb, pos_emb, tokens)
    for i in range(cfg.layers):
        off = 2 + i * 8
        ln1, wq, wk, wv, wo, ln2, w1, w2 = bparams[off:off + 8]
        la_q, lb_q, la_v, lb_v = lparams[i * 4:(i + 1) * 4]
        wq_eff = wq + la_q @ lb_q
        wv_eff = wv + la_v @ lb_v
        h = x + _mha(rmsnorm_ref(x, ln1), wq_eff, wk, wv_eff, wo,
                     cfg.n_heads, use_pallas=False)
        x = h + ffn_ref(rmsnorm_ref(h, ln2), w1, w2)
    return rmsnorm_ref(x, bparams[-1])


def lora_step(cfg: ModelConfig, bparams, lparams, tokens, labels, lr):
    """LoRA fine-tuning step (backbone frozen, low-rank deltas trained)."""
    def loss_fn(lp):
        x = _lora_backbone_fwd(cfg, bparams, lp[:-2], tokens)
        pooled = jnp.mean(x, axis=1)
        logits = pooled @ lp[-2] + lp[-1]
        return softmax_xent(logits, labels)

    loss, grads = jax.value_and_grad(loss_fn)(lparams)
    return tuple(sgd(lparams, grads, lr)) + (loss,)


def houlsby_spec(cfg: ModelConfig, bottleneck: int = 32):
    """Serial Adapters (Houlsby): bottleneck MLP after each layer."""
    d = cfg.d_model
    m = min(bottleneck, d // 2)
    spec = []
    for i in range(cfg.layers):
        spec += [(f"l{i}.ad_down", (d, m)), (f"l{i}.ad_up", (m, d))]
    spec += [("head_w", (d, cfg.n_classes)), ("head_b", (cfg.n_classes,))]
    return spec


def init_houlsby(cfg: ModelConfig, bottleneck: int = 32, seed: int = 3):
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in houlsby_spec(cfg, bottleneck):
        if name.endswith(("ad_up", "head_b")):
            out.append(np.zeros(shape, np.float32))  # identity at init
        else:
            out.append(rng.normal(0.0, 0.02, shape).astype(np.float32))
    return out


def houlsby_step(cfg: ModelConfig, bparams, hparams, tokens, labels, lr):
    """Serial-Adapters fine-tuning step (trainable modules inside the
    backbone — backprop must traverse the whole backbone, which is the
    inefficiency PAC+ removes)."""
    def loss_fn(hp):
        tok_emb, pos_emb = bparams[0], bparams[1]
        x = embed_fwd(cfg, tok_emb, pos_emb, tokens)
        for i in range(cfg.layers):
            off = 2 + i * 8
            lp = bparams[off:off + 8]
            x = _layer_fwd(x, lp, cfg.n_heads, use_pallas=False)
            dn, up = hp[i * 2], hp[i * 2 + 1]
            x = x + jax.nn.gelu(x @ dn) @ up
        x = rmsnorm_ref(x, bparams[-1])
        pooled = jnp.mean(x, axis=1)
        logits = pooled @ hp[-2] + hp[-1]
        return softmax_xent(logits, labels)

    loss, grads = jax.value_and_grad(loss_fn)(hparams)
    return tuple(sgd(hparams, grads, lr)) + (loss,)
