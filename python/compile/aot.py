"""AOT export: lower every PAC+ train/forward function to HLO text.

Build-time entry point (``make artifacts``)::

    python -m compile.aot --config tiny --out ../artifacts/tiny
    python -m compile.aot --config small --baselines --golden --out ...
    python -m compile.aot --config base100m --out ...

Outputs, per config directory:

* ``<name>.hlo.txt``     — one HLO-text module per exported function
* ``params_*.bin``       — raw little-endian parameter dumps (backbone,
                           adapter inits, quantized backbone, baselines)
* ``manifest.json``      — the contract with the Rust runtime: artifact
                           input/output specs and parameter-file layouts
* ``golden.json``        — (tiny only) input/output vectors for Rust
                           integration tests

Interchange format is HLO **text**, not serialized HloModuleProto — the
image's xla_extension 0.5.1 rejects jax>=0.5's 64-bit instruction ids; the
text parser reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .configs import get_config, ModelConfig
from . import model as M
from . import init as I
from . import quantize as Q


# ---------------------------------------------------------------------------
# HLO lowering helpers
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


_DTYPE = {np.dtype(np.float32): "f32", np.dtype(np.int32): "i32",
          np.dtype(np.int8): "i8", np.dtype(np.float16): "f16"}


def _spec_of(x):
    d = np.dtype(x.dtype)
    return {"shape": [int(s) for s in x.shape], "dtype": _DTYPE[d]}


def lower_artifact(name, fn, arg_arrays, out_dir, manifest, input_names=None):
    """Lower fn(*args) to HLO text; record IO specs in the manifest."""
    specs = [jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype)
             for a in arg_arrays]
    # keep_unused: jit would otherwise DCE parameters that a particular
    # artifact does not read (e.g. ln_f in backbone_fwd), silently
    # changing the calling convention the Rust runtime relies on.
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)

    outs = jax.eval_shape(fn, *specs)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    manifest["artifacts"][name] = {
        "file": fname,
        "inputs": [
            dict(_spec_of(s), name=(input_names[i] if input_names else f"arg{i}"))
            for i, s in enumerate(specs)
        ],
        "outputs": [_spec_of(o) for o in outs],
    }
    print(f"  lowered {name}: {len(text)} chars, "
          f"{len(specs)} inputs, {len(outs)} outputs")


# ---------------------------------------------------------------------------
# Parameter dumps
# ---------------------------------------------------------------------------

def dump_params(tag, arrays, names, out_dir, manifest):
    """Concatenate arrays into params_<tag>.bin; record offsets."""
    fname = f"params_{tag}.bin"
    entries = []
    off = 0
    with open(os.path.join(out_dir, fname), "wb") as f:
        for name, a in zip(names, arrays):
            a = np.ascontiguousarray(a)
            raw = a.tobytes()
            entries.append({
                "name": name, "shape": [int(s) for s in a.shape],
                "dtype": _DTYPE[np.dtype(a.dtype)],
                "offset": off, "nbytes": len(raw),
            })
            f.write(raw)
            off += len(raw)
    manifest["params"][tag] = {"file": fname, "entries": entries,
                               "total_bytes": off}
    print(f"  dumped {tag}: {off / 1e6:.1f} MB, {len(entries)} arrays")


# ---------------------------------------------------------------------------
# Export sets
# ---------------------------------------------------------------------------

def export_core(cfg: ModelConfig, out_dir, manifest, stage_sizes):
    """Core artifacts: backbone fwd (full + per-stage), adapter steps."""
    B, S, D, L = cfg.batch, cfg.seq_len, cfg.d_model, cfg.layers
    bspec = M.backbone_spec(cfg)
    aspec = M.adapter_spec(cfg)
    bshapes = [np.zeros(s, np.float32) for _, s in bspec]
    ashapes = [np.zeros(s, np.float32) for _, s in aspec]
    tokens = np.zeros((B, S), np.int32)
    labels = np.zeros((B,), np.int32)
    acts = np.zeros((L + 1, B, S, D), np.float32)
    lr = np.zeros((), np.float32)

    bnames = [n for n, _ in bspec]
    anames = [n for n, _ in aspec]

    # Embedding-only forward (stage 0 prologue of the pipeline).
    lower_artifact(
        "embed_fwd",
        lambda te, pe, tok: (M.embed_fwd(cfg, te, pe, tok),),
        [bshapes[0], bshapes[1], tokens],
        out_dir, manifest, ["tok_emb", "pos_emb", "tokens"])

    # Per-stage forward: k consecutive layers, returns output + cache slab.
    for k in stage_sizes:
        layer_arrays = [np.zeros(s, np.float32)
                        for _, s in bspec[2:2 + k * 8]]
        x_in = np.zeros((B, S, D), np.float32)

        def stage_fn(*args, _k=k):
            lparams, x = list(args[:-1]), args[-1]
            x_out, acts_k = M.backbone_layers_fwd(cfg, lparams, x)
            return (x_out, acts_k)

        lower_artifact(
            f"stage_fwd_k{k}", stage_fn, layer_arrays + [x_in],
            out_dir, manifest,
            [n for n, _ in bspec[2:2 + k * 8]] + ["x"])

    # Whole-backbone forward (standalone / DP baselines, cache building).
    lower_artifact(
        "backbone_fwd",
        lambda *args: (M.backbone_fwd(cfg, list(args[:-1]), args[-1]),),
        bshapes + [tokens], out_dir, manifest, bnames + ["tokens"])

    # Phase-2 hot path: adapter train step on cached activations.
    lower_artifact(
        "adapter_step",
        lambda *args: M.adapter_step(cfg, list(args[:-3]), *args[-3:]),
        ashapes + [acts, labels, lr], out_dir, manifest,
        anames + ["acts", "labels", "lr"])

    # Per-microbatch gradients (for the coordinator's AllReduce).
    lower_artifact(
        "adapter_grads",
        lambda *args: M.adapter_grads(cfg, list(args[:-2]), *args[-2:]),
        ashapes + [acts, labels], out_dir, manifest,
        anames + ["acts", "labels"])

    # Eval pass.
    lower_artifact(
        "adapter_eval",
        lambda *args: M.adapter_eval(cfg, list(args[:-2]), *args[-2:]),
        ashapes + [acts, labels], out_dir, manifest,
        anames + ["acts", "labels"])

    # Epoch-1 fused step (backbone fwd + adapter step + cache emission).
    nb = len(bshapes)
    lower_artifact(
        "full_step",
        lambda *args: M.full_step(cfg, list(args[:nb]),
                                  list(args[nb:-3]), *args[-3:]),
        bshapes + ashapes + [tokens, labels, lr], out_dir, manifest,
        bnames + anames + ["tokens", "labels", "lr"])


def export_quantized(cfg: ModelConfig, backbone, out_dir, manifest):
    """FP16/INT8/INT4 backbone forwards + reduced-precision param dumps."""
    B, S = cfg.batch, cfg.seq_len
    tokens = np.zeros((B, S), np.int32)
    block = min(64, cfg.d_model)

    bnames = [n for n, _ in M.backbone_spec(cfg)]
    f16 = M.fp16_backbone(backbone)
    lower_artifact(
        "qbackbone_fwd_fp16",
        lambda *args: (M.fp16_backbone_fwd(cfg, list(args[:-1]), args[-1]),),
        f16 + [tokens], out_dir, manifest, bnames + ["tokens"])
    dump_params("backbone_fp16", f16, bnames, out_dir, manifest)
    for bits in ("int8", "int4"):
        qparams, qspec = M.quantize_backbone(cfg, backbone, bits, block)
        lower_artifact(
            f"qbackbone_fwd_{bits}",
            lambda *args, _bits=bits: (
                M.quant_backbone_fwd(cfg, list(args[:-1]), args[-1],
                                     _bits, block),),
            qparams + [tokens], out_dir, manifest,
            [n for n, _, _ in qspec] + ["tokens"])
        dump_params(f"backbone_{bits}", qparams,
                    [n for n, _, _ in qspec], out_dir, manifest)


def export_baselines(cfg: ModelConfig, backbone, out_dir, manifest):
    """Full-FT / LoRA / serial-Adapters train steps (accuracy experiments)."""
    B, S = cfg.batch, cfg.seq_len
    tokens = np.zeros((B, S), np.int32)
    labels = np.zeros((B,), np.int32)
    lr = np.zeros((), np.float32)
    bspec = M.backbone_spec(cfg)
    bshapes = [np.zeros(s, np.float32) for _, s in bspec]
    bnames = [n for n, _ in bspec]
    nb = len(bshapes)

    head = [np.zeros((cfg.d_model, cfg.n_classes), np.float32),
            np.zeros((cfg.n_classes,), np.float32)]
    lower_artifact(
        "full_ft_step",
        lambda *args: M.full_ft_step(
            cfg, list(args[:nb]), list(args[nb:nb + 2]), *args[-3:]),
        bshapes + head + [tokens, labels, lr], out_dir, manifest,
        bnames + ["head_w", "head_b", "tokens", "labels", "lr"])

    lspec = M.lora_spec(cfg)
    lshapes = [np.zeros(s, np.float32) for _, s in lspec]
    lower_artifact(
        "lora_step",
        lambda *args: M.lora_step(
            cfg, list(args[:nb]), list(args[nb:-3]), *args[-3:]),
        bshapes + lshapes + [tokens, labels, lr], out_dir, manifest,
        bnames + [n for n, _ in lspec] + ["tokens", "labels", "lr"])
    dump_params("lora", M.init_lora(cfg), [n for n, _ in lspec],
                out_dir, manifest)

    hspec = M.houlsby_spec(cfg)
    hshapes = [np.zeros(s, np.float32) for _, s in hspec]
    lower_artifact(
        "houlsby_step",
        lambda *args: M.houlsby_step(
            cfg, list(args[:nb]), list(args[nb:-3]), *args[-3:]),
        bshapes + hshapes + [tokens, labels, lr], out_dir, manifest,
        bnames + [n for n, _ in hspec] + ["tokens", "labels", "lr"])
    dump_params("houlsby", M.init_houlsby(cfg), [n for n, _ in hspec],
                out_dir, manifest)
    dump_params("head", head, ["head_w", "head_b"], out_dir, manifest)


def export_golden(cfg: ModelConfig, backbone, adapter, out_dir, manifest):
    """Concrete input/output vectors for Rust integration tests."""
    rng = np.random.default_rng(7)
    tokens = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len)).astype(np.int32)
    labels = rng.integers(0, cfg.n_classes, (cfg.batch,)).astype(np.int32)
    lr = np.float32(0.1)

    acts = np.asarray(M.backbone_fwd(cfg, backbone, tokens))
    step_out = M.adapter_step(cfg, [jnp.asarray(a) for a in adapter],
                              jnp.asarray(acts), jnp.asarray(labels),
                              jnp.asarray(lr))
    loss = float(step_out[-1])
    golden = {
        "tokens": tokens.flatten().tolist(),
        "labels": labels.flatten().tolist(),
        "lr": float(lr),
        "acts_sum": float(acts.sum()),
        "acts_l2": float(np.sqrt((acts.astype(np.float64) ** 2).sum())),
        "acts_slice": acts[0, 0, 0, :8].tolist(),
        "adapter_step_loss": loss,
        "new_param0_l2": float(np.linalg.norm(np.asarray(step_out[0]))),
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f, indent=1)
    manifest["golden"] = "golden.json"
    print(f"  golden vectors written (loss={loss:.4f})")


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------

def default_stage_sizes(cfg: ModelConfig):
    """Stage lengths the pipeline planner may pick. All k in 1..L would be
    exhaustive; we export the divisors of L plus 1..min(4, L) which covers
    every balanced partition of up to 8 devices."""
    ks = {k for k in range(1, cfg.layers + 1)
          if cfg.layers % k == 0 or k <= 4}
    return sorted(ks)


def build(config_name: str, out_root: str, baselines: bool, golden: bool,
          inits: str, quant: bool, seed: int = 0):
    cfg = get_config(config_name)
    assert cfg.runnable, f"{config_name} is a cost-model-only descriptor"
    out_dir = os.path.join(out_root)
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"config": cfg.to_dict(), "artifacts": {}, "params": {}}

    print(f"[aot] config={cfg.name} L={cfg.layers} d={cfg.d_model} "
          f"B={cfg.batch} S={cfg.seq_len} "
          f"(backbone {cfg.param_count_backbone()/1e6:.1f}M params, "
          f"adapter {cfg.param_count_adapter()/1e6:.2f}M)")

    backbone = M.init_backbone(cfg, seed)
    bnames = [n for n, _ in M.backbone_spec(cfg)]
    anames = [n for n, _ in M.adapter_spec(cfg)]
    dump_params("backbone", backbone, bnames, out_dir, manifest)

    strategies = [s.strip() for s in inits.split(",") if s.strip()]
    adapter0 = None
    for strat in strategies:
        ap = I.init_adapter(cfg, strat, backbone=backbone, seed=seed + 1)
        dump_params(f"adapter_{strat}", ap, anames, out_dir, manifest)
        if adapter0 is None:
            adapter0 = ap

    export_core(cfg, out_dir, manifest, default_stage_sizes(cfg))
    if quant:
        export_quantized(cfg, backbone, out_dir, manifest)
    if baselines:
        export_baselines(cfg, backbone, out_dir, manifest)
    if golden:
        export_golden(cfg, backbone, adapter0, out_dir, manifest)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest written to {out_dir}/manifest.json "
          f"({len(manifest['artifacts'])} artifacts)")


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--config", default="tiny")
    p.add_argument("--out", default=None,
                   help="output dir (default ../artifacts/<config>)")
    p.add_argument("--baselines", action="store_true",
                   help="also export full-FT/LoRA/serial-adapter steps")
    p.add_argument("--golden", action="store_true",
                   help="emit golden IO vectors for Rust integration tests")
    p.add_argument("--inits", default="prune",
                   help="comma-separated adapter init strategies to dump")
    p.add_argument("--no-quant", action="store_true")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()
    out = args.out or os.path.join("..", "artifacts", args.config)
    build(args.config, out, args.baselines, args.golden, args.inits,
          not args.no_quant, args.seed)


if __name__ == "__main__":
    main()
