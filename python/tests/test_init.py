"""Adapter initialization strategies (paper §IV-C): structural properties
and the convergence ordering the paper's Fig. 14 demonstrates."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.configs import TINY
from compile import model as M
from compile import init as I

CFG = TINY
RNG = np.random.default_rng(9)


@pytest.fixture(scope="module")
def backbone():
    return M.init_backbone(CFG, seed=0)


def _shapes_ok(params):
    spec = M.adapter_spec(CFG)
    assert len(params) == len(spec)
    for (name, shape), p in zip(spec, params):
        assert tuple(p.shape) == tuple(shape), (name, p.shape, shape)


def test_all_strategies_produce_valid_specs(backbone):
    for strat in I.STRATEGIES:
        p = I.init_adapter(CFG, strat, backbone=backbone, distill_steps=5)
        _shapes_ok(p)
        for a in p:
            assert np.isfinite(a).all(), strat


def test_prune_selection_matrices(backbone):
    """w_down columns of the prune init are one-hot channel selectors."""
    p = I.init_prune(CFG, backbone)
    w_down0 = p[0]
    assert set(np.unique(w_down0)) <= {0.0, 1.0}
    assert (w_down0.sum(axis=0) == 1.0).all()       # each column selects one
    assert (w_down0.sum(axis=1) <= 1.0).all()       # channels used once
    w_up = p[-3]
    assert set(np.unique(w_up)) <= {0.0, 1.0}


def test_prune_keeps_top_norm_channels(backbone):
    """Boost one channel's weights; the prune init must select it."""
    bp = [np.array(a) for a in backbone]
    # inflate channel 7 of layer 0's wq rows
    bp[3][7, :] *= 100.0
    p = I.init_prune(CFG, bp)
    idx_selected = np.where(p[0].sum(axis=1) > 0)[0]
    assert 7 in idx_selected


def test_prune_weights_come_from_backbone(backbone):
    """Adapter layer-0 wq must be a submatrix of the backbone's layer-0 wq."""
    p = I.init_prune(CFG, backbone)
    idx = np.where(p[1].sum(axis=1) > 0)[0]          # layer-0 selection
    b_wq = np.asarray(backbone[3])
    a_wq = p[4]                                      # a0.wq
    np.testing.assert_array_equal(a_wq, b_wq[np.ix_(idx, idx)])


def test_distill_reduces_hidden_mse(backbone):
    """The distill loop must reduce the student/teacher hidden-state MSE."""
    tokens = RNG.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)).astype(np.int32)
    acts = M.backbone_fwd(CFG, backbone, tokens, use_pallas=False)

    def hidden_mse(ap):
        h = I._adapter_hidden(CFG, [jnp.asarray(a) for a in ap], acts)
        return float(jnp.mean(jnp.square(h - acts[-1])))

    p0 = I.init_prune(CFG, backbone)
    p1 = I.init_distill(CFG, backbone, steps=60, lr=3e-3)
    assert hidden_mse(p1) < hidden_mse(p0)


def test_zero_init_passes_no_signal(backbone):
    """Zero init's first logits come from head_b alone (all-zero)."""
    p = I.init_zero(CFG)
    tokens = RNG.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)).astype(np.int32)
    acts = M.backbone_fwd(CFG, backbone, tokens, use_pallas=False)
    logits = np.asarray(M.adapter_fwd(CFG, [jnp.asarray(a) for a in p], acts))
    np.testing.assert_array_equal(logits, np.zeros_like(logits))


def test_informed_inits_converge_faster():
    """Fig. 14's ordering on a learnable synthetic task: prune/distill init
    reaches a loss threshold in fewer iterations than gaussian."""
    cfg = CFG
    backbone = M.init_backbone(cfg, seed=0)
    # build a simple separable task: label = (count of token<vocab/2) parity
    rng = np.random.default_rng(3)
    tokens = rng.integers(0, cfg.vocab, (cfg.batch * 4, cfg.seq_len)).astype(np.int32)
    labels = ((tokens < cfg.vocab // 2).sum(axis=1) % 2).astype(np.int32)

    def iterations_to(threshold, ap, max_iters=150):
        params = [jnp.asarray(a) for a in ap]
        lr = jnp.asarray(0.3, jnp.float32)
        for it in range(max_iters):
            tot = 0.0
            for mb in range(4):
                sl = slice(mb * cfg.batch, (mb + 1) * cfg.batch)
                acts = M.backbone_fwd(cfg, backbone, tokens[sl],
                                      use_pallas=False)
                out = M.adapter_step(cfg, params, acts,
                                     jnp.asarray(labels[sl]), lr)
                params, loss = list(out[:-1]), float(out[-1])
                tot += loss
            if tot / 4 < threshold:
                return it
        return max_iters

    it_prune = iterations_to(0.55, I.init_prune(cfg, backbone))
    it_gauss = iterations_to(0.55, M.init_adapter_gaussian(cfg, seed=1))
    assert it_prune <= it_gauss, (it_prune, it_gauss)
