"""Block-wise quantization (paper Eq. 1-2): round-trip bounds + properties."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.quantize import (
    quantize_blockwise, dequantize_blockwise, dequantize_blockwise_jnp,
    quantization_error, quantized_bytes, QMAX, BLOCK,
)

RNG = np.random.default_rng(5)


@settings(max_examples=30, deadline=None)
@given(
    k=st.integers(1, 200),
    n=st.integers(1, 40),
    bits=st.sampled_from(["int8", "int4"]),
    block=st.sampled_from([16, 64]),
)
def test_roundtrip_error_bound(k, n, bits, block):
    """|w - dequant(quant(w))| <= absmax_block / (2 * qmax) per entry."""
    w = RNG.normal(0, 1, (k, n)).astype(np.float32)
    q, s = quantize_blockwise(w, bits, block)
    w2 = dequantize_blockwise(q, s, bits, block)
    qmax = QMAX[bits]
    nblocks = s.shape[0]
    pad = nblocks * block - k
    wp = np.pad(w, ((0, pad), (0, 0))).reshape(nblocks, block, n)
    w2p = np.pad(w2, ((0, pad), (0, 0))).reshape(nblocks, block, n)
    bound = s[:, None, :] / (2 * qmax) + 1e-7
    assert (np.abs(wp - w2p) <= bound).all()


@settings(max_examples=15, deadline=None)
@given(k=st.integers(1, 100), n=st.integers(1, 16))
def test_values_in_range(k, n):
    w = RNG.normal(0, 10, (k, n)).astype(np.float32)
    for bits in ("int8", "int4"):
        q, _ = quantize_blockwise(w, bits)
        assert np.abs(q).max() <= QMAX[bits]


def test_scales_are_block_absmax():
    w = RNG.normal(0, 1, (128, 8)).astype(np.float32)
    _, s = quantize_blockwise(w, "int8", 64)
    want = np.abs(w.reshape(2, 64, 8)).max(axis=1)
    np.testing.assert_allclose(s, want, rtol=1e-6)


def test_zero_block_scale_is_one():
    w = np.zeros((64, 4), np.float32)
    q, s = quantize_blockwise(w)
    assert (s == 1.0).all()
    assert (q == 0).all()


def test_outlier_containment():
    """An outlier only degrades its own block (the point of block-wise)."""
    w = RNG.normal(0, 0.1, (128, 4)).astype(np.float32)
    werr_clean = quantization_error(w, "int8", 64)
    w_out = w.copy()
    w_out[0, 0] = 50.0
    q, s = quantize_blockwise(w_out, "int8", 64)
    w2 = dequantize_blockwise(q, s, "int8", 64)
    # second block untouched by the outlier in the first
    assert np.abs(w2[64:] - w_out[64:]).max() <= np.abs(w_out[64:]).max() / 254 + 1e-7
    # whereas per-tensor quantization would smear ~50/254 error everywhere
    assert np.abs(w2[64:] - w_out[64:]).max() < 50.0 / 254


def test_jnp_matches_numpy_dequant():
    w = RNG.normal(0, 1, (96, 8)).astype(np.float32)
    for bits in ("int8", "int4"):
        q, s = quantize_blockwise(w, bits, 32)
        a = dequantize_blockwise(q, s, bits, 32)
        b = np.asarray(dequantize_blockwise_jnp(q, s, bits, 32))
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


def test_int4_coarser_than_int8():
    w = RNG.normal(0, 1, (256, 16)).astype(np.float32)
    assert quantization_error(w, "int4") > quantization_error(w, "int8")


def test_quantized_bytes():
    # 128x64 int8: values 8192 B + scales 2*64*4 B
    assert quantized_bytes((128, 64), "int8", 64) == 128 * 64 + 2 * 64 * 4
    # int4 packs two values per byte
    assert quantized_bytes((128, 64), "int4", 64) == 128 * 64 // 2 + 2 * 64 * 4


def test_rejects_non_2d():
    with pytest.raises(ValueError):
        quantize_blockwise(np.zeros((2, 2, 2), np.float32))
