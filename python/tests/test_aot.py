"""AOT export: manifest contract, HLO text validity, golden reproducibility."""

import json
import os

import numpy as np
import pytest

from compile.configs import TINY
from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "tiny")


@pytest.fixture(scope="module")
def tiny_artifacts(tmp_path_factory):
    """Use the checked-out artifacts dir if present, else build into tmp."""
    if os.path.exists(os.path.join(ART, "manifest.json")):
        return ART
    out = str(tmp_path_factory.mktemp("tiny_artifacts"))
    aot.build("tiny", out, baselines=True, golden=True,
              inits="gaussian,prune", quant=True)
    return out


@pytest.fixture(scope="module")
def manifest(tiny_artifacts):
    with open(os.path.join(tiny_artifacts, "manifest.json")) as f:
        return json.load(f)


EXPECTED_ARTIFACTS = {
    "embed_fwd", "backbone_fwd", "adapter_step", "adapter_grads",
    "adapter_eval", "full_step", "qbackbone_fwd_int8", "qbackbone_fwd_int4",
}


def test_manifest_artifacts_present(manifest, tiny_artifacts):
    assert EXPECTED_ARTIFACTS <= set(manifest["artifacts"])
    for name, art in manifest["artifacts"].items():
        path = os.path.join(tiny_artifacts, art["file"])
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, f"{name} is not HLO text"


def test_manifest_io_specs_match_model(manifest):
    cfg = TINY
    art = manifest["artifacts"]["adapter_step"]
    aspec = M.adapter_spec(cfg)
    # inputs: adapter params + acts + labels + lr
    assert len(art["inputs"]) == len(aspec) + 3
    for (name, shape), inp in zip(aspec, art["inputs"]):
        assert inp["name"] == name
        assert tuple(inp["shape"]) == tuple(shape)
    acts_in = art["inputs"][len(aspec)]
    assert acts_in["shape"] == [cfg.layers + 1, cfg.batch, cfg.seq_len,
                                cfg.d_model]
    # outputs: updated params + loss
    assert len(art["outputs"]) == len(aspec) + 1


def test_stage_artifacts_cover_partitions(manifest):
    cfg = TINY
    ks = sorted(int(n.split("stage_fwd_k")[1])
                for n in manifest["artifacts"] if n.startswith("stage_fwd_k"))
    # every layer count 1..L must be composable from exported stage sizes
    assert 1 in ks
    assert cfg.layers in ks or cfg.layers % max(ks) == 0


def test_param_dump_roundtrip(manifest, tiny_artifacts):
    """Binary dump + offsets reproduce the exact backbone arrays."""
    cfg = TINY
    backbone = M.init_backbone(cfg, seed=0)
    entry = manifest["params"]["backbone"]
    raw = open(os.path.join(tiny_artifacts, entry["file"]), "rb").read()
    assert len(raw) == entry["total_bytes"]
    for (name, shape), e in zip(M.backbone_spec(cfg), entry["entries"]):
        assert e["name"] == name
        a = np.frombuffer(raw[e["offset"]:e["offset"] + e["nbytes"]],
                          dtype=np.float32).reshape(e["shape"])
        np.testing.assert_array_equal(a, backbone.pop(0))


def test_quantized_dump_dtypes(manifest):
    entries = manifest["params"]["backbone_int8"]["entries"]
    qs = [e for e in entries if e["name"].endswith(".q")]
    ss = [e for e in entries if e["name"].endswith(".s")]
    assert qs and len(qs) == len(ss)
    for e in qs:
        assert e["dtype"] == "i8"
        assert e["nbytes"] == int(np.prod(e["shape"]))


def test_golden_reproducible(manifest, tiny_artifacts):
    """Re-deriving the golden outputs from seeds must match the file."""
    import jax.numpy as jnp
    with open(os.path.join(tiny_artifacts, manifest["golden"])) as f:
        golden = json.load(f)
    cfg = TINY
    backbone = M.init_backbone(cfg, seed=0)
    tokens = np.array(golden["tokens"], np.int32).reshape(cfg.batch, cfg.seq_len)
    acts = np.asarray(M.backbone_fwd(cfg, backbone, tokens))
    assert abs(acts.sum() - golden["acts_sum"]) < 1e-2 * max(1, abs(golden["acts_sum"]))
    np.testing.assert_allclose(acts[0, 0, 0, :8], golden["acts_slice"],
                               rtol=1e-5, atol=1e-6)


def test_default_stage_sizes():
    ks = aot.default_stage_sizes(TINY)
    assert ks == [1, 2]
    from compile.configs import BASE100M
    ks = aot.default_stage_sizes(BASE100M)
    assert set([1, 2, 3, 4, 6, 12]) <= set(ks)
