"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
swept across shapes/dtypes with hypothesis (the CORE correctness signal)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    attention_ref, block_dequant_matmul_ref, adapter_combine_ref,
    rmsnorm_ref, ffn_ref,
)
from compile.kernels.attention import flash_attention
from compile.kernels.quant_matmul import block_dequant_matmul
from compile.kernels.adapter_combine import adapter_combine
from compile.quantize import quantize_blockwise, QMAX

RNG = np.random.default_rng(1234)


def randn(*shape, scale=1.0):
    return (RNG.normal(0, scale, shape)).astype(np.float32)


# ---------------------------------------------------------------------------
# block_dequant_matmul
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([1, 3, 8, 16, 33]),
    kb=st.sampled_from([1, 2, 3, 4]),
    n=st.sampled_from([8, 16, 48, 96]),
    block=st.sampled_from([32, 64]),
    bits=st.sampled_from(["int8", "int4"]),
)
def test_quant_matmul_matches_ref(m, kb, n, block, bits):
    k = kb * block
    x = randn(m, k)
    w = randn(k, n)
    w_q, scales = quantize_blockwise(w, bits, block)
    qmax = QMAX[bits]
    got = np.asarray(block_dequant_matmul(x, w_q, scales, qmax=qmax, block=block))
    want = np.asarray(block_dequant_matmul_ref(x, w_q, scales, qmax=qmax, block=block))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_quant_matmul_outlier_blocks():
    """Outliers in one block must not poison other blocks (paper §IV-D)."""
    k, n = 128, 16
    w = randn(k, n, scale=0.1)
    w[3, 5] = 100.0  # outlier confined to block 0
    w_q, scales = quantize_blockwise(w, "int8", 64)
    x = np.eye(k, dtype=np.float32)[:8]
    got = np.asarray(block_dequant_matmul(x, w_q, scales, block=64))
    # rows 0..7 of dequant(w) — block 1 rows unaffected by the outlier
    w2 = np.asarray(block_dequant_matmul_ref(np.eye(k, dtype=np.float32),
                                             w_q, scales, block=64))
    np.testing.assert_allclose(got, w2[:8], rtol=1e-5, atol=1e-5)
    assert np.abs(w2[64:] - w[64:]).max() < 0.01 * 0.1 * 64


def test_quant_matmul_rejects_bad_k():
    x = randn(4, 65)
    w_q = np.zeros((65, 8), np.int8)
    s = np.ones((2, 8), np.float32)
    with pytest.raises(AssertionError):
        block_dequant_matmul(x, w_q, s, block=64)


def test_quant_matmul_zero_weights():
    x = randn(4, 64)
    w_q, s = quantize_blockwise(np.zeros((64, 8), np.float32))
    got = np.asarray(block_dequant_matmul(x, w_q, s))
    np.testing.assert_array_equal(got, np.zeros((4, 8), np.float32))


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 2]),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([8, 16, 32, 64]),
    dh=st.sampled_from([8, 16, 32]),
)
def test_attention_matches_ref(b, h, s, dh):
    q, k, v = randn(b, h, s, dh), randn(b, h, s, dh), randn(b, h, s, dh)
    got = np.asarray(flash_attention(q, k, v))
    want = np.asarray(attention_ref(q, k, v))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_attention_large_logits_stable():
    """Online softmax must survive large score magnitudes."""
    q = randn(1, 1, 32, 16, scale=30.0)
    k = randn(1, 1, 32, 16, scale=30.0)
    v = randn(1, 1, 32, 16)
    got = np.asarray(flash_attention(q, k, v))
    want = np.asarray(attention_ref(q, k, v))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_attention_uniform_when_keys_equal():
    """Identical keys => output = mean of values."""
    q = randn(1, 2, 16, 8)
    k = np.broadcast_to(randn(1, 2, 1, 8), (1, 2, 16, 8)).copy()
    v = randn(1, 2, 16, 8)
    got = np.asarray(flash_attention(q, k, v))
    want = np.broadcast_to(v.mean(axis=2, keepdims=True), got.shape)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_attention_odd_blocking():
    """Non-default tile sizes must not change the result."""
    q, k, v = randn(1, 2, 48, 16), randn(1, 2, 48, 16), randn(1, 2, 48, 16)
    a = np.asarray(flash_attention(q, k, v, bq=16, kv_chunk=12))
    b = np.asarray(flash_attention(q, k, v, bq=48, kv_chunk=48))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# adapter_combine
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    s=st.sampled_from([4, 16, 32, 64]),
    d=st.sampled_from([16, 32, 64]),
    r=st.sampled_from([2, 4, 8]),
    lam=st.floats(0.0, 1.0),
)
def test_adapter_combine_matches_ref(s, d, r, lam):
    da = max(2, d // r)
    b = randn(s, d)
    a = randn(s, da)
    w = randn(d, da)
    got = np.asarray(adapter_combine(b, a, w, lam))
    want = np.asarray(adapter_combine_ref(b, a, w, np.float32(lam)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_adapter_combine_lambda_extremes():
    """lam=0 passes the adapter state through; lam=1 is pure projection."""
    b, a, w = randn(8, 32), randn(8, 8), randn(32, 8)
    np.testing.assert_allclose(
        np.asarray(adapter_combine(b, a, w, 0.0)), a, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(adapter_combine(b, a, w, 1.0)), b @ w, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# helper oracles sanity
# ---------------------------------------------------------------------------

def test_rmsnorm_unit_scale():
    x = randn(4, 16)
    y = np.asarray(rmsnorm_ref(x, np.ones(16, np.float32)))
    rms = np.sqrt((y ** 2).mean(axis=-1))
    np.testing.assert_allclose(rms, np.ones(4), rtol=1e-3)


def test_ffn_zero_weights():
    x = randn(4, 16)
    y = np.asarray(ffn_ref(x, np.zeros((16, 32), np.float32),
                           np.zeros((32, 16), np.float32)))
    np.testing.assert_array_equal(y, np.zeros_like(x))
