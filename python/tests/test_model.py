"""L2 model semantics: shapes, the frozen-backbone invariants that make the
PAC+ activation cache sound, and trainability of every step variant."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.configs import TINY, SMALL, get_config
from compile import model as M

CFG = TINY
RNG = np.random.default_rng(42)


@pytest.fixture(scope="module")
def setup():
    bp = M.init_backbone(CFG, seed=0)
    ap = M.init_adapter_gaussian(CFG, seed=1)
    tokens = RNG.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)).astype(np.int32)
    labels = RNG.integers(0, CFG.n_classes, (CFG.batch,)).astype(np.int32)
    return bp, ap, tokens, labels


def test_spec_counts():
    assert len(M.backbone_spec(CFG)) == 3 + CFG.layers * 8
    assert len(M.adapter_spec(CFG)) == 4 + CFG.layers * 10
    for cfg_name in ("tiny", "small", "base100m"):
        cfg = get_config(cfg_name)
        spec = M.backbone_spec(cfg)
        n = sum(int(np.prod(s)) for _, s in spec)
        assert n == cfg.param_count_backbone()
        aspec = M.adapter_spec(cfg)
        na = sum(int(np.prod(s)) for _, s in aspec)
        assert na == cfg.param_count_adapter()


def test_base100m_is_about_100m():
    cfg = get_config("base100m")
    assert 80e6 < cfg.param_count_backbone() < 120e6
    # adapter must be a small fraction (parameter efficiency)
    assert cfg.param_count_adapter() < 0.05 * cfg.param_count_backbone()


def test_backbone_fwd_shape(setup):
    bp, _, tokens, _ = setup
    acts = M.backbone_fwd(CFG, bp, tokens)
    assert acts.shape == (CFG.layers + 1, CFG.batch, CFG.seq_len, CFG.d_model)
    assert np.isfinite(np.asarray(acts)).all()


def test_backbone_pallas_matches_ref_path(setup):
    bp, _, tokens, _ = setup
    a = np.asarray(M.backbone_fwd(CFG, bp, tokens, use_pallas=True))
    b = np.asarray(M.backbone_fwd(CFG, bp, tokens, use_pallas=False))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_activation_cache_invariance(setup):
    """Same input sequence => identical backbone activations, regardless of
    adapter state — the property that makes the activation cache sound
    (paper §IV-B, Observation 2)."""
    bp, ap, tokens, labels = setup
    acts1 = np.asarray(M.backbone_fwd(CFG, bp, tokens))
    # mutate the adapter arbitrarily; backbone activations must not change
    out = M.full_step(CFG, bp, ap, tokens, labels, 0.5)
    acts2 = np.asarray(out[-1])
    acts3 = np.asarray(M.backbone_fwd(CFG, bp, tokens))
    np.testing.assert_array_equal(acts1, acts2)
    np.testing.assert_array_equal(acts1, acts3)


def test_cached_step_equals_full_step(setup):
    """adapter_step on cached activations == full_step's adapter update."""
    bp, ap, tokens, labels = setup
    acts = M.backbone_fwd(CFG, bp, tokens)
    full = M.full_step(CFG, bp, ap, tokens, labels, 0.1)
    cached = M.adapter_step(CFG, [jnp.asarray(a) for a in ap], acts,
                            jnp.asarray(labels), jnp.asarray(0.1, jnp.float32))
    assert np.allclose(float(full[-2]), float(cached[-1]))
    for f, c in zip(full[:-2], cached[:-1]):
        np.testing.assert_allclose(np.asarray(f), np.asarray(c),
                                   rtol=1e-6, atol=1e-7)


def test_gradient_highway_no_backbone_grads(setup):
    """Gradients must never touch the backbone: full_step returns only
    adapter updates; backbone arrays are bit-identical afterwards."""
    bp, ap, tokens, labels = setup
    before = [np.asarray(p).copy() for p in bp]
    M.full_step(CFG, bp, ap, tokens, labels, 0.1)
    for b, a in zip(before, bp):
        np.testing.assert_array_equal(b, np.asarray(a))


def test_adapter_step_changes_params_and_reduces_loss(setup):
    bp, ap, tokens, labels = setup
    acts = M.backbone_fwd(CFG, bp, tokens)
    params = [jnp.asarray(a) for a in ap]
    lr = jnp.asarray(0.2, jnp.float32)
    losses = []
    for _ in range(20):
        out = M.adapter_step(CFG, params, acts, jnp.asarray(labels), lr)
        params, loss = list(out[:-1]), float(out[-1])
        losses.append(loss)
    assert losses[-1] < losses[0], f"no learning: {losses[0]} -> {losses[-1]}"


def test_adapter_grads_match_step(setup):
    """grads artifact + SGD applied externally == adapter_step output."""
    bp, ap, tokens, labels = setup
    acts = M.backbone_fwd(CFG, bp, tokens)
    params = [jnp.asarray(a) for a in ap]
    gout = M.adapter_grads(CFG, params, acts, jnp.asarray(labels))
    grads, gloss = list(gout[:-1]), float(gout[-1])
    sout = M.adapter_step(CFG, params, acts, jnp.asarray(labels),
                          jnp.asarray(0.1, jnp.float32))
    assert np.allclose(gloss, float(sout[-1]))
    for p, g, s in zip(params, grads, sout[:-1]):
        np.testing.assert_allclose(np.asarray(p - 0.1 * g), np.asarray(s),
                                   rtol=1e-5, atol=1e-7)


def test_quant_backbone_close_to_f32(setup):
    bp, _, tokens, _ = setup
    acts = np.asarray(M.backbone_fwd(CFG, bp, tokens))
    for bits, tol in (("int8", 0.02), ("int4", 0.30)):
        qp, _ = M.quantize_backbone(CFG, bp, bits)
        qacts = np.asarray(M.quant_backbone_fwd(CFG, qp, tokens, bits))
        rel = np.abs(qacts - acts).max() / np.abs(acts).max()
        assert rel < tol, f"{bits}: rel err {rel}"


def test_quant_backbone_pallas_matches_jnp(setup):
    bp, _, tokens, _ = setup
    qp, _ = M.quantize_backbone(CFG, bp, "int8")
    a = np.asarray(M.quant_backbone_fwd(CFG, qp, tokens, "int8", use_pallas=True))
    b = np.asarray(M.quant_backbone_fwd(CFG, qp, tokens, "int8", use_pallas=False))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_adapter_eval_counts(setup):
    bp, ap, tokens, labels = setup
    acts = M.backbone_fwd(CFG, bp, tokens)
    loss, correct = M.adapter_eval(CFG, [jnp.asarray(a) for a in ap],
                                   acts, jnp.asarray(labels))
    assert 0 <= int(correct) <= CFG.batch
    assert float(loss) > 0


def test_baseline_steps_learn(setup):
    """Each baseline fine-tuning algorithm reduces loss on a fixed batch."""
    bp, _, tokens, labels = setup
    bp = [jnp.asarray(p) for p in bp]
    lr = jnp.asarray(0.05, jnp.float32)

    lparams = [jnp.asarray(p) for p in M.init_lora(CFG)]
    l0 = None
    for _ in range(10):
        out = M.lora_step(CFG, bp, lparams, tokens, labels, lr)
        lparams, loss = list(out[:-1]), float(out[-1])
        l0 = l0 if l0 is not None else loss
    assert loss < l0

    hparams = [jnp.asarray(p) for p in M.init_houlsby(CFG)]
    l0 = None
    for _ in range(10):
        out = M.houlsby_step(CFG, bp, hparams, tokens, labels, lr)
        hparams, loss = list(out[:-1]), float(out[-1])
        l0 = l0 if l0 is not None else loss
    assert loss < l0

    head = [jnp.zeros((CFG.d_model, CFG.n_classes)), jnp.zeros((CFG.n_classes,))]
    nb = len(bp)
    l0 = None
    for _ in range(10):
        out = M.full_ft_step(CFG, bp, head, tokens, labels, lr)
        bp, head, loss = list(out[:nb]), list(out[nb:nb + 2]), float(out[-1])
        l0 = l0 if l0 is not None else loss
    assert loss < l0


def test_lora_init_is_identity(setup):
    """LoRA B=0 at init => logits identical to frozen backbone + zero head
    delta (paper §IV-C's rationale)."""
    bp, _, tokens, _ = setup
    lp = M.init_lora(CFG)
    x_lora = np.asarray(M._lora_backbone_fwd(
        CFG, [jnp.asarray(p) for p in bp], [jnp.asarray(p) for p in lp[:-2]],
        tokens))
    acts = np.asarray(M.backbone_fwd(CFG, bp, tokens, use_pallas=False))
    from compile.kernels.ref import rmsnorm_ref
    want = np.asarray(rmsnorm_ref(jnp.asarray(acts[-1]), jnp.asarray(bp[-1])))
    np.testing.assert_allclose(x_lora, want, rtol=1e-5, atol=1e-6)


def test_small_config_end_to_end():
    cfg = SMALL
    bp = M.init_backbone(cfg, seed=0)
    ap = M.init_adapter_gaussian(cfg, seed=1)
    tokens = RNG.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len)).astype(np.int32)
    labels = RNG.integers(0, cfg.n_classes, (cfg.batch,)).astype(np.int32)
    out = M.full_step(cfg, bp, ap, tokens, labels, 0.1)
    assert out[-1].shape == (cfg.layers + 1, cfg.batch, cfg.seq_len, cfg.d_model)
    assert np.isfinite(float(out[-2]))


def test_fp16_backbone_close_to_f32(setup):
    bp, _, tokens, _ = setup
    acts = np.asarray(M.backbone_fwd(CFG, bp, tokens))
    f16 = M.fp16_backbone(bp)
    assert all(p.dtype == np.float16 for p in f16)
    qacts = np.asarray(M.fp16_backbone_fwd(CFG, f16, tokens))
    rel = np.abs(qacts - acts).max() / np.abs(acts).max()
    assert rel < 5e-3, f"fp16 rel err {rel}"


def test_fp16_halves_storage(setup):
    bp, _, _, _ = setup
    f32_bytes = sum(p.nbytes for p in bp)
    f16_bytes = sum(p.nbytes for p in M.fp16_backbone(bp))
    assert f16_bytes * 2 == f32_bytes
